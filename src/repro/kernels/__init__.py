"""Pallas TPU kernels (each: kernel.py + ops.py wrapper + ref.py oracle).

sparse_conv      -- the paper's direct sparse convolution (CSR + weight
                    stretching + dynamic indexing), TPU-adapted
bsr_matmul       -- beyond-paper block-sparse matmul on the MXU
flash_attention  -- fused attention (fwd + custom-vjp bwd); removes the
                    T^2 logits HBM traffic the rooflines flagged
"""
