"""Pallas TPU kernels (each: kernel.py + ops.py wrapper + ref.py oracle).

sparse_conv      -- the paper's direct sparse convolution (CSR + weight
                    stretching + dynamic indexing), TPU-adapted
bsr_matmul       -- beyond-paper block-sparse matmul on the MXU
bsr_conv         -- beyond-paper block-sparse (BCSR) direct convolution on
                    the MXU: on-chip im2col patch gather + per-tile
                    systolic contraction for moderately-sparse layers
flash_attention  -- fused attention (fwd + custom-vjp bwd); removes the
                    T^2 logits HBM traffic the rooflines flagged
"""
