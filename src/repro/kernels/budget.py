"""One source of truth for on-chip memory budgets and fit arithmetic.

Every schedule decision in the stack — the ELL (``kernels/sparse_conv``)
and BCSR (``kernels/bsr_conv``) conv wrappers, the autotuner's candidate
pruning (``tuning/space.py``), and the pre-flight static verifier
(``repro.analysis``) — must agree on two things: how much VMEM/SMEM a
schedule's working set occupies, and how much the hardware offers.  Those
formulas used to be split between the two kernel ``ops.py`` modules (with
the budget constants re-declared in two more); this module is the single
home for both, so a budget change (new chip generation, different Mosaic
headroom) or a working-set term (a new scratch buffer) lands in exactly
one place.

The fit helpers take the budget as an explicit parameter defaulting to the
canonical constants — the kernel wrappers pass their own (monkeypatchable)
module aliases through, which keeps the historical test seams
(``monkeypatch.setattr(ops, "_VMEM_BUDGET", ...)``) working while the
arithmetic itself lives here.
"""
from __future__ import annotations

# VMEM budget the autotuner packs blocks into (bytes).  v5e has ~16 MiB of
# VMEM per core; leave headroom for Mosaic's own buffers and semaphores.
VMEM_BUDGET = 12 * 1024 * 1024
# SMEM budget for the scalar-prefetched operands: packed index array + int32
# nnz row + f32 bias row (ELL), or block-column table + nblocks row (BCSR).
SMEM_BUDGET = 2 * 1024 * 1024


def halo_extent(t: int, stride: int, r: int) -> int:
    """Input rows/cols one output tile of ``t`` positions touches."""
    return (t - 1) * stride + r


# Storage width (bytes) of each supported sparse-value dtype.  The quantised
# dtypes (int8 / fp8) store one byte per nonzero plus a per-output-channel
# f32 scale row accounted separately (SMEM for ELL, VMEM for BCSR).
VALUE_ITEMSIZES = {
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int8": 1,
    "float8_e4m3fn": 1,
}


def value_itemsize(dtype: str) -> int:
    """Bytes per stored sparse value for ``dtype`` (a dtype name string)."""
    try:
        return VALUE_ITEMSIZES[dtype]
    except KeyError:
        raise ValueError(
            f"unknown sparse value dtype {dtype!r}; expected one of "
            f"{sorted(VALUE_ITEMSIZES)}") from None


# -- ELL direct sparse conv (kernels/sparse_conv) ---------------------------

def ell_smem_bytes(m: int, k: int, quantized: bool = False) -> int:
    """SMEM footprint of the ELL kernel's scalar-prefetched operands:
    packed indices (M*K int32), the int32 nnz row (M*4 — the kernel's
    per-row loop bounds), and the f32 bias row (M*4).  A quantised bank
    scalar-prefetches a fourth operand, the f32 per-channel scale row
    (M*4)."""
    return m * k * 4 + m * 4 + m * 4 + (m * 4 if quantized else 0)


def smem_fits(m: int, k: int, quantized: bool = False, *,
              smem_budget: int = None) -> bool:
    """All scalar-prefetched operands fit the SMEM budget; omitting
    the nnz row used to let index-heavy layers overshoot."""
    budget = SMEM_BUDGET if smem_budget is None else smem_budget
    return ell_smem_bytes(m, k, quantized) <= budget


def ell_vmem_bytes(m: int, c: int, e: int, f: int, k: int, r: int, s: int,
                   stride: int, tm: int, te: int, tf: int,
                   fuse_res: bool = False, pipeline: bool = False,
                   value_itemsize: int = 4) -> int:
    """VMEM working set of one ELL (tm, te, tf) tiling: halo'd input block
    + value block + f32 out tile (+ the residual input tile when the fused
    epilogue accumulates a shortcut).  ``pipeline=True`` accounts the
    double-buffered halo DMA schedule: two halo-block scratch buffers are
    live at once, so the staged-input term doubles.  ``value_itemsize``
    prices the (tm, K) value block at its storage width — 4 for f32 banks,
    1 for int8/fp8 quantised ones (the scale row lives in SMEM, see
    :func:`ell_smem_bytes`)."""
    x_bytes = c * halo_extent(te, stride, r) * halo_extent(tf, stride, s) * 4
    if pipeline:
        x_bytes *= 2
    out_bytes = tm * te * tf * 4
    res_bytes = out_bytes if fuse_res else 0
    return x_bytes + tm * k * value_itemsize + out_bytes + res_bytes


def tiling_fits(m: int, c: int, e: int, f: int, k: int, r: int, s: int,
                stride: int, tm: int, te: int, tf: int,
                fuse_res: bool = False, pipeline: bool = False,
                *, value_itemsize: int = 4, vmem_budget: int = None) -> bool:
    """Whether one ELL (tm, te, tf) tiling's working set fits VMEM."""
    if tm < 1 or m % tm:
        return False
    budget = VMEM_BUDGET if vmem_budget is None else vmem_budget
    return ell_vmem_bytes(m, c, e, f, k, r, s, stride, tm, te, tf,
                          fuse_res=fuse_res, pipeline=pipeline,
                          value_itemsize=value_itemsize) <= budget


# -- BCSR MXU conv (kernels/bsr_conv) ---------------------------------------

def bsr_smem_bytes(gbm: int, kb: int) -> int:
    """SMEM footprint of the BCSR kernel's scalar-prefetched operands: the
    int32 block-column table (gbm*KB) and the int32 nblocks row (gbm)."""
    return gbm * kb * 4 + gbm * 4


def bsr_smem_fits(gbm: int, kb: int, *, smem_budget: int = None) -> bool:
    """Both scalar-prefetched BCSR operands fit the SMEM budget."""
    budget = SMEM_BUDGET if smem_budget is None else smem_budget
    return bsr_smem_bytes(gbm, kb) <= budget


def bsr_vmem_bytes(c: int, r: int, s: int, stride: int, bm: int, bn: int,
                   te: int, tf: int, itemsize: int = 4,
                   fuse_res: bool = False,
                   value_itemsize: int = None,
                   quantized: bool = False) -> int:
    """VMEM working set of one BCSR (te, tf) spatial tiling: halo'd input
    block + (bm, bn) weight tile + (bn, te, tf) patch tile + f32 out tile
    (+ the residual input tile when fused).  ``value_itemsize`` prices the
    weight tile at its storage width (defaults to the input ``itemsize``);
    a quantised bank additionally streams a (1, bm) f32 scale tile
    (``quantized=True``)."""
    x_bytes = c * halo_extent(te, stride, r) * halo_extent(tf, stride, s) * itemsize
    w_bytes = bm * bn * (itemsize if value_itemsize is None else value_itemsize)
    patch_bytes = bn * te * tf * itemsize
    out_bytes = bm * te * tf * 4
    res_bytes = out_bytes if fuse_res else 0
    scale_bytes = bm * 4 if quantized else 0
    return x_bytes + w_bytes + patch_bytes + out_bytes + res_bytes + scale_bytes


def bsr_tiling_fits(c: int, r: int, s: int, stride: int, bm: int, bn: int,
                    te: int, tf: int, itemsize: int = 4,
                    fuse_res: bool = False, *,
                    value_itemsize: int = None, quantized: bool = False,
                    vmem_budget: int = None) -> bool:
    """Whether one BCSR (te, tf) spatial tiling's working set fits VMEM."""
    budget = VMEM_BUDGET if vmem_budget is None else vmem_budget
    return bsr_vmem_bytes(c, r, s, stride, bm, bn, te, tf, itemsize=itemsize,
                          fuse_res=fuse_res, value_itemsize=value_itemsize,
                          quantized=quantized) <= budget
