"""Pallas TPU kernel for Escoin's direct sparse convolution.

TPU adaptation of the paper's GPU kernel (Section 3.2/3.3):

  GPU thread block per output channel      -> grid cell per (image, channel tile)
  warp over consecutive ``w`` (coalescing) -> the (E, F) output window lives in
                                              VREG lanes; each nonzero issues one
                                              full-width FMA over the window
  CSR value/colidx in shared memory        -> packed (c,r,s) indices in SMEM via
                                              scalar prefetch; values in VMEM
  inputs via read-only texture cache       -> the whole (C, Hp, Wp) padded input
                                              for one image staged HBM->VMEM once
                                              and reused by every nonzero of every
                                              channel in the tile
  partial sums in registers                -> float32 accumulator in VMEM out block
  rowptr loop bound                        -> fori_loop bounded by the true row nnz
                                              (padding entries are never touched)

The kernel is specialised for stride == 1 (the common case in the paper's
models); strided layers fall back to the pure-JAX direct path — the analogue
of the paper's per-parameter-region "kernel customization".

Index packing: each nonzero's (c, r, s) is packed into one int32 as
``c * (R*S) + r * S + s`` to keep the SMEM footprint at M*K*4 bytes; the
kernel decodes with two divmods (scalar ALU, off the critical VPU path).
This is exactly the paper's *weight stretching* trade-off: more index
arithmetic in exchange for fewer memory bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, nnz_ref,            # scalar prefetch (SMEM)
            x_ref, val_ref,              # VMEM in
            out_ref,                     # VMEM out
            *, tm: int, k: int, rs: int, s: int, e: int, f: int):
    mt = pl.program_id(1)

    def channel(ml, _):
        m = mt * tm + ml

        def body(kk, acc):
            packed = idx_ref[m, kk]
            c = packed // rs
            rem = packed - c * rs
            r = rem // s
            ss = rem - r * s
            # Dynamic-start static-size window: the direct-indexing load.
            win = x_ref[0, c, pl.ds(r, e), pl.ds(ss, f)]
            return acc + val_ref[ml, kk].astype(jnp.float32) * win.astype(jnp.float32)

        acc0 = jnp.zeros((e, f), dtype=jnp.float32)
        # CSR semantics: iterate only this row's true nonzeros.
        acc = lax.fori_loop(0, nnz_ref[m], body, acc0)
        out_ref[0, ml, :, :] = acc
        return 0

    lax.fori_loop(0, tm, channel, 0, unroll=True)


@functools.partial(
    jax.jit, static_argnames=("tm", "k", "rs", "s", "e", "f", "interpret"))
def sparse_conv_pallas(xpad: jax.Array, value: jax.Array, packed_idx: jax.Array,
                       nnz: jax.Array, *, tm: int, k: int, rs: int, s: int,
                       e: int, f: int, interpret: bool = False) -> jax.Array:
    """Launch the direct sparse conv kernel.

    Args:
      xpad:       (N, C, Hp, Wp) pre-padded input (the paper's pad_in step).
      value:      (M, K) ELL values.
      packed_idx: (M, K) int32, c*(R*S) + r*S + s.
      nnz:        (M,) int32 true row lengths.
      tm:         output-channel tile (VMEM/occupancy knob).
      e, f:       output spatial dims (stride 1: e = Hp - R + 1 etc.).

    Returns: (N, M, E, F) float32.
    """
    n, c, hp, wp = xpad.shape
    m = value.shape[0]
    assert m % tm == 0, (m, tm)
    grid = (n, m // tm)
    return pl.pallas_call(
        functools.partial(_kernel, tm=tm, k=k, rs=rs, s=s, e=e, f=f),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, c, hp, wp), lambda ni, mt, idx, nnz_: (ni, 0, 0, 0)),
                pl.BlockSpec((tm, k), lambda ni, mt, idx, nnz_: (mt, 0)),
            ],
            out_specs=pl.BlockSpec((1, tm, e, f),
                                   lambda ni, mt, idx, nnz_: (ni, mt, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, m, e, f), jnp.float32),
        interpret=interpret,
    )(packed_idx, nnz, xpad, value)
