"""Pallas TPU kernel for Escoin's direct sparse convolution.

TPU adaptation of the paper's GPU kernel (Section 3.2/3.3):

  GPU thread block per output channel      -> grid cell per (image, spatial
                                              tile, channel tile)
  warp over consecutive ``w`` (coalescing) -> the (TE, TF) output tile lives in
                                              VREG lanes; each nonzero issues
                                              one full-width FMA over the tile
  CSR value/colidx in shared memory        -> packed (c,r,s) indices in SMEM via
                                              scalar prefetch; values in VMEM
  inputs via read-only texture cache       -> the halo'd (C, halo_h, halo_w)
                                              input block for one spatial tile
                                              DMA'd HBM->VMEM once and reused by
                                              every nonzero of every channel
                                              tile of that cell
  partial sums in registers                -> float32 accumulator in VMEM out
                                              block
  rowptr loop bound                        -> fori_loop bounded by the true row
                                              nnz (padding entries never touched)

Spatial tiling: the grid is (N, ceil(E/TE), ceil(F/TF), M/TM).  Each spatial
cell stages a *halo'd* input block of ``(TE-1)*stride + R`` by
``(TF-1)*stride + S`` rows/cols — overlapping blocks cannot be expressed with
blocked BlockSpecs, so the input stays in HBM (``memory_space=ANY``) and the
kernel issues an explicit sliced DMA into VMEM scratch.  This removes the
whole-padded-image-in-VMEM restriction: arbitrarily large feature maps run
through the kernel as long as one halo'd block fits the budget.

Double-buffered halo DMA pipeline (``pipeline=True``): the blocking schedule
staged each cell's block with ``start(); wait()`` back to back, so the VPU
idled for the entire HBM->VMEM copy of every spatial cell.  The pipelined
schedule allocates **two** halo scratch buffers with per-buffer DMA
semaphores and software-pipelines the grid: on the *last* channel tile of
spatial cell *i* the kernel resolves the (image, et, ft) indices of cell
*i+1* from its linearised cell id and kicks off that cell's DMA into the
other buffer, so the copy flies while cell *i*'s remaining FMA work (and
cell *i+1*'s first channel tile's SMEM decode) executes.  Cell *i+1* then
only *waits* on its semaphore at ``mt == 0`` — by which point the copy has
had a full channel-tile loop to complete.  Buffers alternate by cell parity
(consecutive linear cells never share a slot), and the warm-up DMA for cell
0 is issued (then immediately waited) at the first grid step, which is the
one copy the pipeline cannot hide.  ``pipeline=False`` keeps the
single-buffer blocking schedule for tilings where doubling the halo block
would bust VMEM.

Strides: each nonzero reads a dynamic-start window of extent
``(T-1)*stride + 1`` and applies a *static* ``[::stride]`` slice — the same
dynamic-start-slice-plus-static-stride trick as ``core/direct_conv.py`` —
so ``stride >= 1`` runs in-kernel instead of falling back to pure JAX.

Edge tiles: TE/TF need not divide E/F.  The grid uses ceiling division;
Pallas drops out-of-range output writes, and the input is zero-padded so the
last tile's halo window stays in bounds (the extra zeros only ever feed
discarded output positions).

Index packing: each nonzero's (c, r, s) is packed into one int32 as
``c * (R*S) + r * S + s`` to keep the SMEM footprint at M*K*4 bytes; the
kernel decodes with two divmods (scalar ALU, off the critical VPU path).
This is exactly the paper's *weight stretching* trade-off: more index
arithmetic in exchange for fewer memory bytes.

Load balancing: the kernel itself is permutation-agnostic — feed it an
nnz-balanced bank (``core/sparse_format.py:balance_ell_conv``, rows sorted
by descending nnz) and each TM-tile's unrolled channel loop runs rows of
near-equal length instead of being bounded by its worst row; ``ops.py``
applies the inverse permutation to the output (and the forward permutation
to bias/residual) so callers never see the reordering.

Fused epilogue: the per-channel bias rides along as a third scalar-prefetch
operand (f32 in SMEM, one scalar per output channel) and is added to the f32
accumulator before the single output write; a static ``fuse_relu`` flag
clamps the accumulator in-register, and an optional residual operand —
blocked exactly like the output tile — is accumulated for bottleneck tails
(``conv → bias → +shortcut → ReLU``).  Compared to the unfused executor this
removes two to three extra HBM round-trips of the full output tensor: the
accumulator leaves VMEM exactly once, epilogue applied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(*refs,                       # scalar prefetch (SMEM), then VMEM
            tm: int, rs: int, s: int, stride: int, te: int, tf: int,
            halo_h: int, halo_w: int, fuse_relu: bool, has_res: bool,
            quantized: bool, pipeline: bool, et_n: int, ft_n: int,
            n_cells: int):
    # Scalar-prefetched operands lead: packed indices, nnz row, bias row,
    # and — for a quantised bank — the f32 per-channel scale row.  Then the
    # HBM/ANY halo-padded input, the VMEM value block, the optional residual
    # tile, the output tile, and the scratch buffers.
    if quantized:
        idx_ref, nnz_ref, bias_ref, scale_ref, x_ref, val_ref, *rest = refs
    else:
        scale_ref = None
        idx_ref, nnz_ref, bias_ref, x_ref, val_ref, *rest = refs
    if has_res:
        res_ref, out_ref, xblk_ref, sem = rest
    else:
        res_ref = None
        out_ref, xblk_ref, sem = rest
    ni = pl.program_id(0)
    et = pl.program_id(1)
    ft = pl.program_id(2)
    mt = pl.program_id(3)
    mt_n = pl.num_programs(3)

    if pipeline:
        # Linearised spatial-cell id; buffers alternate by cell parity, so
        # the prefetch for cell i+1 never lands in the buffer cell i reads.
        cell = (ni * et_n + et) * ft_n + ft
        slot = lax.rem(cell, 2)

        def cell_dma(slot_i, ni_i, et_i, ft_i):
            return pltpu.make_async_copy(
                x_ref.at[ni_i, :, pl.ds(et_i * te * stride, halo_h),
                         pl.ds(ft_i * tf * stride, halo_w)],
                xblk_ref.at[slot_i], sem.at[slot_i])

        @pl.when(mt == 0)
        def _arrive():
            # Warm-up: cell 0 has no predecessor to prefetch it, so its
            # copy is issued here — the one DMA the pipeline cannot hide.
            @pl.when(cell == 0)
            def _warmup():
                cell_dma(slot, ni, et, ft).start()
            # Every other cell's DMA was started on the predecessor's last
            # channel tile; the shape-matched descriptor waits it out.
            cell_dma(slot, ni, et, ft).wait()

        @pl.when(jnp.logical_and(mt == mt_n - 1, cell + 1 < n_cells))
        def _prefetch():
            # Resolve the successor cell's (image, et, ft) in-kernel from
            # its linear id and start its copy into the *other* buffer while
            # this cell's remaining FMA work computes.
            nxt = cell + 1
            ni2 = nxt // (et_n * ft_n)
            rem2 = lax.rem(nxt, et_n * ft_n)
            et2 = rem2 // ft_n
            ft2 = lax.rem(rem2, ft_n)
            cell_dma(lax.rem(nxt, 2), ni2, et2, ft2).start()
    else:
        slot = None

        # Blocking schedule: stage the halo'd block once per (image, spatial
        # tile); the channel-tile loop is the innermost grid dim, so the
        # block persists in scratch across every mt of this cell (TPU grids
        # run sequentially).
        @pl.when(mt == 0)
        def _stage():
            dma = pltpu.make_async_copy(
                x_ref.at[ni, :, pl.ds(et * te * stride, halo_h),
                         pl.ds(ft * tf * stride, halo_w)],
                xblk_ref, sem)
            dma.start()
            dma.wait()

    # Dynamic-start window extent for a static [::stride] landing exactly on
    # the TE (resp. TF) output positions of this tile.
    e_ext = (te - 1) * stride + 1
    f_ext = (tf - 1) * stride + 1

    def channel(ml, _):
        m = mt * tm + ml

        def body(kk, acc):
            packed = idx_ref[m, kk]
            c = packed // rs
            rem = packed - c * rs
            r = rem // s
            ss = rem - r * s
            if pipeline:
                win = xblk_ref[slot, c, pl.ds(r, e_ext), pl.ds(ss, f_ext)]
            else:
                win = xblk_ref[c, pl.ds(r, e_ext), pl.ds(ss, f_ext)]
            win = win[::stride, ::stride]
            v = val_ref[ml, kk].astype(jnp.float32)
            if quantized:
                # Dequantise at the FMA: multiply the int8/fp8 value by its
                # row's f32 scale *before* the window product — the exact
                # multiply ``dequantize`` performs host-side, so this kernel
                # is bit-identical to the f32 kernel on a dequantised bank.
                v = v * scale_ref[m]
            return acc + v * win.astype(jnp.float32)

        acc0 = jnp.zeros((te, tf), dtype=jnp.float32)
        # CSR semantics: iterate only this row's true nonzeros.
        acc = lax.fori_loop(0, nnz_ref[m], body, acc0)
        # Fused epilogue on the in-register f32 accumulator: one output
        # write instead of separate bias / residual / ReLU HBM passes.
        acc = acc + bias_ref[m]
        if has_res:
            acc = acc + res_ref[0, ml, :, :].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        out_ref[0, ml, :, :] = acc
        return 0

    lax.fori_loop(0, tm, channel, 0, unroll=True)


@functools.partial(
    jax.jit,
    static_argnames=("tm", "k", "rs", "s", "e", "f", "stride", "te", "tf",
                     "fuse_relu", "pipeline", "interpret"))
def sparse_conv_pallas(xpad: jax.Array, value: jax.Array, packed_idx: jax.Array,
                       nnz: jax.Array, bias: jax.Array,
                       residual: jax.Array | None = None,
                       scale: jax.Array | None = None, *, tm: int, k: int,
                       rs: int, s: int, e: int, f: int, stride: int = 1,
                       te: int | None = None, tf: int | None = None,
                       fuse_relu: bool = False, pipeline: bool = False,
                       interpret: bool = False) -> jax.Array:
    """Launch the spatially-tiled direct sparse conv kernel.

    Args:
      xpad:       (N, C, Hp, Wp) pre-padded input (the paper's pad_in step).
      value:      (M, K) ELL values — f32, or int8/fp8 for a quantised bank
                  (``scale`` required; dequantised in-register at the FMA).
      packed_idx: (M, K) int32, c*(R*S) + r*S + s.
      nnz:        (M,) int32 true row lengths.
      bias:       (M,) f32 per-channel bias, added to the f32 accumulator
                  in-kernel (pass zeros for a bias-free conv — the add is
                  then a bitwise no-op).
      residual:   optional (N, M, E, F) shortcut accumulated before the ReLU
                  (bottleneck tail), blocked like the output tile.
      scale:      optional (M,) f32 per-output-channel quantisation scales,
                  scalar-prefetched as a fourth SMEM operand; each value is
                  multiplied by its row's scale before the window product,
                  so accumulation stays f32 throughout.
      tm:         output-channel tile (VMEM/occupancy knob); must divide M.
      e, f:       output spatial dims ((Hp - R) // stride + 1 etc.).
      stride:     conv stride (>= 1), applied in-kernel.
      te, tf:     output spatial tile dims (default: whole output, i.e. the
                  untiled schedule).  Need not divide e/f — edge tiles are
                  handled by ceiling-division grids + masked writes.
      fuse_relu:  clamp the accumulator in-kernel (the fused epilogue).
      pipeline:   double-buffer the halo DMA — two scratch buffers, the copy
                  for spatial cell i+1 issued while cell i computes — at the
                  cost of a second halo-block's VMEM.  False keeps the
                  single-buffer blocking schedule.

    Returns: (N, M, E, F) float32.
    """
    n, c, hp, wp = xpad.shape
    m = value.shape[0]
    if tm < 1 or m % tm:
        # A stale tuned plan (or caller typo) must surface loudly even under
        # ``python -O`` — an assert would vanish and the BlockSpecs would
        # silently mis-tile the channel axis.
        raise ValueError(
            f"channel tile tm={tm} does not divide M={m} "
            f"(geometry: n={n} c={c} hp={hp} wp={wp} k={k} rs={rs} "
            f"stride={stride} e={e} f={f})")
    te = e if te is None else min(te, e)
    tf = f if tf is None else min(tf, f)
    r = rs // s
    halo_h = (te - 1) * stride + r
    halo_w = (tf - 1) * stride + s
    et_n = pl.cdiv(e, te)
    ft_n = pl.cdiv(f, tf)
    # Zero-pad so the *last* tile's halo window stays in bounds; the extra
    # rows/cols only ever feed output positions >= E/F, which Pallas drops.
    need_h = (et_n * te - 1) * stride + r
    need_w = (ft_n * tf - 1) * stride + s
    if need_h > hp or need_w > wp:
        xpad = jnp.pad(xpad, ((0, 0), (0, 0), (0, max(0, need_h - hp)),
                              (0, max(0, need_w - wp))))
    grid = (n, et_n, ft_n, m // tm)
    has_res = residual is not None
    quantized = scale is not None
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((tm, k), lambda ni, et, ft, mt, *_: (mt, 0)),
    ]
    if quantized:
        inputs = [packed_idx, nnz, bias, scale, xpad, value]
    else:
        inputs = [packed_idx, nnz, bias, xpad, value]
    if has_res:
        in_specs.append(pl.BlockSpec(
            (1, tm, te, tf), lambda ni, et, ft, mt, *_: (ni, mt, et, ft)))
        inputs.append(residual)
    if pipeline:
        scratch = [pltpu.VMEM((2, c, halo_h, halo_w), xpad.dtype),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        scratch = [pltpu.VMEM((c, halo_h, halo_w), xpad.dtype),
                   pltpu.SemaphoreType.DMA]
    return pl.pallas_call(
        functools.partial(_kernel, tm=tm, rs=rs, s=s, stride=stride,
                          te=te, tf=tf, halo_h=halo_h, halo_w=halo_w,
                          fuse_relu=fuse_relu, has_res=has_res,
                          quantized=quantized, pipeline=pipeline,
                          et_n=et_n, ft_n=ft_n, n_cells=n * et_n * ft_n),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4 if quantized else 3,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, tm, te, tf),
                lambda ni, et, ft, mt, *_: (ni, mt, et, ft)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((n, m, e, f), jnp.float32),
        interpret=interpret,
    )(*inputs)
