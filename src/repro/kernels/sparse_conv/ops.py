"""Jit'd public wrapper around the direct sparse conv Pallas kernel.

Handles: input padding (pad_in), index packing, channel-tile autotuning
(the paper's kernel-customisation table), the stride>1 fallback to the
pure-JAX direct path, and dtype policy (bf16/f32 in, f32 accumulate).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.direct_conv import direct_sparse_conv
from repro.core.sparse_format import EllConv, ell_from_dense_conv
from repro.kernels.sparse_conv.kernel import sparse_conv_pallas

# VMEM budget the autotuner packs blocks into (bytes).  v5e has ~16 MiB of
# VMEM per core; leave headroom for Mosaic's own buffers and semaphores.
_VMEM_BUDGET = 12 * 1024 * 1024
# SMEM budget for the scalar-prefetched packed index array.
_SMEM_BUDGET = 2 * 1024 * 1024

# Public aliases consumed by repro.tuning (candidate-space pruning).
VMEM_BUDGET = _VMEM_BUDGET
SMEM_BUDGET = _SMEM_BUDGET

_TM_LADDER = (128, 64, 32, 16, 8, 4, 2, 1)


def tm_candidates(m: int, c: int, hp: int, wp: int, e: int, f: int,
                  k: int) -> List[int]:
    """All output-channel tiles that divide M and fit the VMEM budget,
    largest first.

    Working set per grid cell = input block + value block + f32 out block.
    This is the search space the ``repro.tuning`` autotuner measures over;
    ``choose_tm`` below is its static heuristic seed (largest feasible tile).
    """
    x_bytes = c * hp * wp * 4
    out: List[int] = []
    for tm in _TM_LADDER:
        if m % tm:
            continue
        val_bytes = tm * k * 4
        out_bytes = tm * e * f * 4
        if x_bytes + val_bytes + out_bytes <= _VMEM_BUDGET:
            out.append(tm)
    return out or [1]


def choose_tm(m: int, c: int, hp: int, wp: int, e: int, f: int, k: int) -> int:
    """Pick the largest output-channel tile whose VMEM working set fits.

    Mirrors the paper's per-layer kernel specialisation: small, few-channel
    layers get a big TM (amortise the input stage-in); huge feature maps get
    TM=1.  The measurement-driven refinement lives in ``repro.tuning``.
    """
    return tm_candidates(m, c, hp, wp, e, f, k)[0]


def pack_indices(ell: EllConv) -> jax.Array:
    """Pack (c, r, s) into one int32 per nonzero: c*(R*S) + r*S + s."""
    _, _, r, s = ell.shape
    return (ell.cidx * (r * s) + ell.ridx * s + ell.sidx).astype(jnp.int32)


def sparse_conv(x: jax.Array, ell: EllConv, *, stride: int = 1,
                padding: int = 0, tm: Optional[int] = None,
                interpret: bool = False) -> jax.Array:
    """Direct sparse convolution, Pallas-accelerated where specialised.

    (N, C, H, W) input, ELL filter bank for (M, C, R, S) weights ->
    (N, M, E, F) in x.dtype.
    """
    m, c, r, s = ell.shape
    k = ell.k
    if stride != 1 or m * k * 4 > _SMEM_BUDGET:
        # Kernel customisation fallback: strided / index-heavy layers use the
        # pure-JAX direct path (same algorithm, XLA-scheduled).
        return direct_sparse_conv(x, ell, stride=stride, padding=padding)
    n, _, h, w = x.shape
    xpad = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    hp, wp = h + 2 * padding, w + 2 * padding
    e, f = hp - r + 1, wp - s + 1
    if tm is None:
        tm = choose_tm(m, c, hp, wp, e, f, k)
    out = sparse_conv_pallas(
        xpad, ell.value, pack_indices(ell), ell.nnz,
        tm=tm, k=k, rs=r * s, s=s, e=e, f=f, interpret=interpret)
    return out.astype(x.dtype)


def sparse_conv_from_dense(x: jax.Array, w_dense, *, stride: int = 1,
                           padding: int = 0, interpret: bool = False) -> jax.Array:
    """Convenience: prune-format-and-run from a dense (M, C, R, S) weight."""
    ell = ell_from_dense_conv(np.asarray(w_dense))
    return sparse_conv(x, ell, stride=stride, padding=padding, interpret=interpret)
