"""Jit'd public wrapper around the direct sparse conv Pallas kernel.

Handles: input padding (pad_in), index packing, tile selection — output
channels ``tm`` and output spatial tiles ``(te, tf)``, the paper's
kernel-customisation table — dtype policy (bf16/f32 in, f32 accumulate),
the fused epilogue (bias / ReLU / bottleneck residual applied to the f32
accumulator in-kernel, one output write instead of three HBM passes), the
halo DMA schedule (``pipeline=True`` double-buffers the staged input block
so the copy for spatial cell i+1 overlaps cell i's compute; auto-enabled
whenever the second halo buffer fits VMEM), nnz-balanced banks (an
``EllConv`` carrying a row permutation runs the kernel in balanced row
order — bias/residual are permuted in, the output is inverse-permuted
back, so callers never see the reordering), and the fallback to the
pure-JAX direct path for layers whose packed index array busts the SMEM
budget or for which no VMEM-feasible tiling exists — the fallback applies
the same epilogue unfused, so ``sparse_conv`` is a complete conv+epilogue
operator either way.

Strided layers and feature maps larger than VMEM run through the Pallas
kernel: the kernel tiles the output spatially with halo'd input blocks and
applies the stride in-kernel, so the old stride==1 / whole-image-in-VMEM
restrictions are gone.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.direct_conv import direct_sparse_conv, out_spatial
from repro.core.sparse_format import (EllConv, dequantize,
                                      ell_from_dense_conv,
                                      inverse_permutation)
from repro.kernels import budget
from repro.kernels.budget import (halo_extent,  # noqa: F401  (re-export)
                                  value_itemsize)
from repro.kernels.sparse_conv.kernel import sparse_conv_pallas
from repro.telemetry.fallback import record_fallback

# Budget constants live in ``repro.kernels.budget`` (one source of truth for
# kernels, tuner, and the static verifier); these module aliases stay so
# existing callers — and tests that monkeypatch them — keep working.  The
# fit wrappers below re-read the aliases at call time and pass them through.
_VMEM_BUDGET = budget.VMEM_BUDGET
_SMEM_BUDGET = budget.SMEM_BUDGET

# Public aliases consumed by repro.tuning (candidate-space pruning).
VMEM_BUDGET = _VMEM_BUDGET
SMEM_BUDGET = _SMEM_BUDGET

_TM_LADDER = (128, 64, 32, 16, 8, 4, 2, 1)
# Output spatial tile ladder (besides the untiled full extent).
_SPATIAL_LADDER = (128, 64, 32, 16, 8)


def smem_fits(m: int, k: int, quantized: bool = False) -> bool:
    """All scalar-prefetched operands fit the SMEM budget: packed indices
    (M*K int32), the int32 nnz row (M*4 — the kernel's per-row loop bounds;
    omitting it used to let index-heavy layers overshoot), the f32 bias row
    (M*4), and — for a quantised bank — the f32 per-channel scale row
    (another M*4)."""
    return budget.smem_fits(m, k, quantized, smem_budget=_SMEM_BUDGET)


def spatial_candidates(e: int) -> List[int]:
    """Output tile extents to consider for one spatial axis, largest first.

    The full extent (untiled) comes first — when it fits it is the best
    schedule (no halo re-fetch); the ladder below it trades halo overlap for
    a bounded VMEM block on large feature maps.
    """
    return [e] + [t for t in _SPATIAL_LADDER if t < e]


def tm_candidates(m: int, c: int, hp: int, wp: int, e: int, f: int,
                  k: int, value_itemsize: int = 4) -> List[int]:
    """Output-channel tiles that divide M and fit VMEM with the *whole*
    padded image staged (the untiled spatial schedule), largest first.

    Returns ``[]`` when even TM=1 busts the budget — callers must then tile
    spatially (``tile_candidates``) or fall back to the pure-JAX path.
    Returning ``[1]`` here used to launch an over-budget kernel.
    ``value_itemsize`` prices the value block at its storage width (1 for
    int8/fp8 quantised banks).
    """
    x_bytes = c * hp * wp * 4
    out: List[int] = []
    for tm in _TM_LADDER:
        if m % tm:
            continue
        val_bytes = tm * k * value_itemsize
        out_bytes = tm * e * f * 4
        if x_bytes + val_bytes + out_bytes <= _VMEM_BUDGET:
            out.append(tm)
    return out


def tiling_fits(m: int, c: int, e: int, f: int, k: int, r: int, s: int,
                stride: int, tm: int, te: int, tf: int,
                fuse_res: bool = False, pipeline: bool = False,
                value_itemsize: int = 4) -> bool:
    """Whether one (tm, te, tf) tiling's working set — halo'd input block +
    value block + f32 out tile (+ the residual input tile when the fused
    epilogue accumulates a shortcut) — fits the VMEM budget.

    ``pipeline=True`` accounts the double-buffered halo DMA schedule: two
    halo-block scratch buffers are live at once (the one being computed on
    and the one being prefetched), so the staged-input term doubles.
    ``value_itemsize`` prices the value block at its storage width."""
    return budget.tiling_fits(m, c, e, f, k, r, s, stride, tm, te, tf,
                              fuse_res=fuse_res, pipeline=pipeline,
                              value_itemsize=value_itemsize,
                              vmem_budget=_VMEM_BUDGET)


def tile_candidates(m: int, c: int, e: int, f: int, k: int, r: int, s: int,
                    stride: int = 1,
                    tms: Optional[Tuple[int, ...]] = None,
                    fuse_res: bool = False, pipeline: bool = False,
                    value_itemsize: int = 4,
                    ) -> List[Tuple[int, int, int]]:
    """All (tm, te, tf) tilings whose VMEM working set fits, preferred first.

    Preference order: fewest spatial cells (least halo re-fetch), then least
    total staged input traffic, then largest tm — so when the whole image
    fits, the first candidate is the old untiled schedule with the largest
    feasible channel tile.  ``tms`` overrides the channel-tile ladder (e.g.
    a caller-pinned tm that the ladder doesn't contain); ``fuse_res``
    reserves VMEM for the fused epilogue's residual input tile; ``pipeline``
    for the double-buffered halo schedule's second scratch block;
    ``value_itemsize`` prices the value block at its storage width.
    """
    out: List[Tuple[int, int, int]] = []
    for te in spatial_candidates(e):
        for tf in spatial_candidates(f):
            for tm in (tms or _TM_LADDER):
                if tiling_fits(m, c, e, f, k, r, s, stride, tm, te, tf,
                               fuse_res=fuse_res, pipeline=pipeline,
                               value_itemsize=value_itemsize):
                    out.append((tm, te, tf))

    def pref(cand: Tuple[int, int, int]) -> Tuple[int, int, int]:
        tm, te, tf = cand
        cells = -(-e // te) * (-(-f // tf))
        staged = cells * c * halo_extent(te, stride, r) * halo_extent(tf, stride, s)
        return (cells, staged, -tm)

    return sorted(out, key=pref)


def choose_tiles(m: int, c: int, e: int, f: int, k: int, r: int, s: int,
                 stride: int = 1) -> Optional[Tuple[int, int, int]]:
    """Static heuristic seed: the preferred feasible (tm, te, tf), or None
    when no tiling fits (caller falls back to the pure-JAX direct path)."""
    cands = tile_candidates(m, c, e, f, k, r, s, stride)
    return cands[0] if cands else None


def choose_tm(m: int, c: int, hp: int, wp: int, e: int, f: int, k: int) -> int:
    """Pick the largest output-channel tile whose untiled-spatial VMEM
    working set fits.

    Mirrors the paper's per-layer kernel specialisation: small, few-channel
    layers get a big TM (amortise the input stage-in); the measurement-driven
    refinement lives in ``repro.tuning``.  Raises when nothing fits — use
    ``choose_tiles`` (spatial tiling) for such layers.
    """
    cands = tm_candidates(m, c, hp, wp, e, f, k)
    if not cands:
        raise ValueError(
            f"no feasible untiled tm for m={m} c={c} hp={hp} wp={wp}; "
            "the feature map needs spatial tiling (choose_tiles)")
    return cands[0]


def pack_indices(ell: EllConv) -> jax.Array:
    """Pack (c, r, s) into one int32 per nonzero: c*(R*S) + r*S + s."""
    _, _, r, s = ell.shape
    return (ell.cidx * (r * s) + ell.ridx * s + ell.sidx).astype(jnp.int32)


def apply_epilogue(y: jax.Array, bias: Optional[jax.Array],
                   fuse_relu: bool,
                   residual: Optional[jax.Array]) -> jax.Array:
    """The unfused conv epilogue: same math as the kernel's fused one,
    applied as separate ops on the f32 result, then cast back to the input
    dtype.  The single definition the fallback path, the wall-clock
    runners, and the benchmark epilogue rows all share."""
    dtype = y.dtype
    y = y.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)[None, :, None, None]
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jax.nn.relu(y)
    return y.astype(dtype)


def resolve_schedule(m: int, c: int, e: int, f: int, k: int, r: int, s: int,
                     stride: int, *, tm: Optional[int] = None,
                     te: Optional[int] = None, tf: Optional[int] = None,
                     fuse_res: bool = False,
                     pipeline: Optional[bool] = None,
                     value_dtype: str = "float32",
                     ) -> Tuple[Optional[Tuple[int, int, int, bool]],
                                Optional[str]]:
    """The dispatch decision ``sparse_conv`` makes, as a pure function.

    Returns ``((tm, te, tf, pipeline), None)`` for the schedule the Pallas
    kernel would run, or ``(None, reason)`` — a ``telemetry.fallback``
    reason code — when the layer falls back to the pure-JAX direct path.
    Factored out so the engine's ExecutionReport and the benchmark's
    zero-fallback invariant can ask "what would this layer execute?"
    without launching anything; ``sparse_conv`` itself dispatches through
    this same function.

    ``value_dtype`` names the bank's storage dtype: a quantised bank
    (int8 / float8_e4m3fn) shrinks the VMEM value block to one byte per
    nonzero but scalar-prefetches an extra f32 scale row in SMEM — both
    accounted here so feasibility matches what the kernel would allocate.
    """
    vsize = value_itemsize(value_dtype)
    quantized = vsize == 1
    if not smem_fits(m, k, quantized):
        # Index-heavy layers: packed indices cannot be scalar-prefetched.
        return None, "smem_infeasible"
    if tm is not None and te is not None and tf is not None:
        # Fully-specified tiling (tuned plan / caller override): honor it
        # when it fits, never launch an over-budget kernel.
        te, tf = min(te, e), min(tf, f)
        if tm < 1 or m % tm:
            return None, "nondividing_tm"
        if not tiling_fits(m, c, e, f, k, r, s, stride, tm, te, tf,
                           fuse_res=fuse_res, value_itemsize=vsize):
            return None, "no_feasible_tiling"
    else:
        # A pinned tm need not sit on the default ladder (e.g. tm=24 for
        # m=48): enumerate spatial tiles for exactly that tm.
        if tm is not None and (tm < 1 or m % tm):
            return None, "nondividing_tm"
        cands = tile_candidates(m, c, e, f, k, r, s, stride,
                                tms=None if tm is None else (tm,),
                                fuse_res=fuse_res, value_itemsize=vsize)
        if te is not None:
            cands = [t for t in cands if t[1] == min(te, e)]
        if tf is not None:
            cands = [t for t in cands if t[2] == min(tf, f)]
        if not cands:
            # No in-budget tiling (or the requested one is infeasible).
            return None, "no_feasible_tiling"
        tm, te, tf = cands[0]
    # Halo DMA schedule: double-buffer when allowed *and* the second halo
    # scratch block fits; otherwise the single-buffer blocking path.
    if pipeline is None or pipeline:
        pipeline = tiling_fits(m, c, e, f, k, r, s, stride, tm, te, tf,
                               fuse_res=fuse_res, pipeline=True,
                               value_itemsize=vsize)
    return (tm, te, tf, bool(pipeline)), None


def sparse_conv(x: jax.Array, ell: EllConv, *, stride: int = 1,
                padding: int = 0, tm: Optional[int] = None,
                te: Optional[int] = None, tf: Optional[int] = None,
                bias: Optional[jax.Array] = None, fuse_relu: bool = False,
                residual: Optional[jax.Array] = None,
                pipeline: Optional[bool] = None,
                interpret: bool = False,
                layer: Optional[str] = None) -> jax.Array:
    """Direct sparse convolution + fused epilogue, Pallas-accelerated.

    (N, C, H, W) input, ELL filter bank for (M, C, R, S) weights ->
    (N, M, E, F) in x.dtype.  Any stride >= 1 runs in-kernel; tm/te/tf
    default to the static heuristic (``choose_tiles``) and are the knobs
    the ``repro.tuning`` autotuner turns.  ``bias`` (per-channel),
    ``fuse_relu`` and ``residual`` (a shortcut tensor shaped like the
    output) execute in-kernel on the f32 accumulator so the output is
    written to HBM exactly once.

    ``pipeline`` selects the halo DMA schedule: ``True`` double-buffers the
    staged input block (the copy for spatial cell i+1 overlaps cell i's
    compute), ``False`` forces the single-buffer blocking schedule, and
    ``None`` (default) auto-enables double buffering whenever the second
    halo block also fits VMEM.  A requested ``pipeline=True`` that busts
    the budget silently drops to the single-buffer path — same math,
    blocking staging — never to the pure-JAX fallback.

    An nnz-balanced bank (``ell.perm`` set, see
    ``core.sparse_format.balance_ell_conv``) runs the kernel in balanced
    row order: bias/residual are gathered into bank order on the way in and
    the output is inverse-permuted on the way out, so results are
    bit-identical to the natural-order bank (per-row accumulation order is
    untouched).  Falls back to the pure-JAX direct path — with the
    identical epilogue applied unfused — only when the packed index array
    busts the SMEM budget or no VMEM-feasible tiling exists; any such
    fallback is reported through ``telemetry.record_fallback`` (one-time
    warning + gated counters), ``layer`` naming the conv op when the
    caller knows it.
    """
    m, c, r, s = ell.shape
    k = ell.k
    inv = inverse_permutation(ell.perm) if ell.perm is not None else None
    n, _, h, w = x.shape
    e, f = out_spatial(h, w, r, s, stride, padding)
    fuse_res = residual is not None

    def fallback(reason: str) -> jax.Array:
        record_fallback(
            "sparse_conv", reason, layer=layer,
            geometry=(f"m={m} c={c} e={e} f={f} k={k} r={r} s={s} "
                      f"stride={stride}"),
            fallback_to="csr-direct")
        # The pure-JAX direct path multiplies values in their storage dtype;
        # a quantised bank must be dequantised first so the fallback computes
        # the same f32 math as the kernel's in-register scale.
        y = direct_sparse_conv(x, dequantize(ell), stride=stride,
                               padding=padding)
        if inv is not None:
            # The bank's rows are in balanced order; restore channel order
            # before the (caller-ordered) epilogue.
            y = jnp.take(y, inv, axis=1)
        return apply_epilogue(y, bias, fuse_relu, residual)

    sched, reason = resolve_schedule(m, c, e, f, k, r, s, stride, tm=tm,
                                     te=te, tf=tf, fuse_res=fuse_res,
                                     pipeline=pipeline,
                                     value_dtype=ell.value_dtype)
    if sched is None:
        # The XLA-scheduled direct path, with the same epilogue unfused.
        return fallback(reason)
    tm, te, tf, pipeline = sched
    xpad = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    b = (jnp.zeros((m,), jnp.float32) if bias is None
         else jnp.asarray(bias, jnp.float32))
    res = residual
    if ell.perm is not None:
        # Balanced bank: the kernel computes bank-row-ordered output, so its
        # per-row epilogue operands must be gathered into bank order too.
        b = jnp.take(b, ell.perm, axis=0)
        if res is not None:
            res = jnp.take(res, ell.perm, axis=1)
    out = sparse_conv_pallas(
        xpad, ell.value, pack_indices(ell), ell.nnz, b, res,
        scale=ell.scale,
        tm=tm, k=k, rs=r * s, s=s, e=e, f=f, stride=stride, te=te, tf=tf,
        fuse_relu=fuse_relu, pipeline=pipeline, interpret=interpret)
    if inv is not None:
        out = jnp.take(out, inv, axis=1)
    return out.astype(x.dtype)


def sparse_conv_from_dense(x: jax.Array, w_dense, *, stride: int = 1,
                           padding: int = 0, interpret: bool = False) -> jax.Array:
    """Convenience: prune-format-and-run from a dense (M, C, R, S) weight."""
    ell = ell_from_dense_conv(np.asarray(w_dense))
    return sparse_conv(x, ell, stride=stride, padding=padding, interpret=interpret)
