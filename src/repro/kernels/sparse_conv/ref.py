"""Pure-jnp oracle for the direct sparse conv kernel.

The oracle is XLA's dense convolution over the zero-filled weights — sparsity
is a performance transform, not a semantic one, so dense conv defines the
ground truth (same contract the paper uses: CUBLAS output == Escort output).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def sparse_conv_ref(x: jax.Array, w_dense: jax.Array, *, stride: int = 1,
                    padding: int = 0) -> jax.Array:
    """(N, C, H, W) x (M, C, R, S) -> (N, M, E, F), float32 accumulate."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32), w_dense.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32)
