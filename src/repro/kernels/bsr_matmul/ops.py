"""Jit'd public wrapper for the BCSR MXU matmul kernel.

Handles batch flattening/padding, batch-tile autotuning, and the dtype
policy (inputs as given, float32 accumulate, cast back on exit).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_format import BcsrMatrix
from repro.kernels.budget import VMEM_BUDGET as _VMEM_BUDGET
from repro.kernels.bsr_matmul.kernel import bsr_matmul_pallas


def choose_tb(b: int, bm: int, bn: int, itemsize: int) -> int:
    """Largest batch tile whose (x tile + out tile + weight tile) fits VMEM.

    The MXU wants >=128 rows; going bigger amortises the weight-tile fetch
    across more batch rows (weight reuse — the paper's Fig. 7 argument).
    """
    for tb in (1024, 512, 256, 128, 64, 32, 16, 8):
        if b % tb:
            continue
        need = tb * bn * itemsize + tb * bm * 4 + bm * bn * itemsize
        if need <= _VMEM_BUDGET:
            return tb
    return 8


def bsr_matmul(x: jax.Array, w: BcsrMatrix, *, tb: Optional[int] = None,
               interpret: bool = False) -> jax.Array:
    """y = x @ W.T for BCSR weight W of logical shape (M, N).

    x: (..., N) any leading batch dims.  Returns (..., M) in x.dtype.
    """
    m, n = w.shape
    bm, bn = w.block
    lead = x.shape[:-1]
    xb = x.reshape(-1, x.shape[-1])
    b = xb.shape[0]
    pad_n = (-n) % bn
    if pad_n:
        xb = jnp.pad(xb, ((0, 0), (0, pad_n)))
    if tb is None:
        tb = choose_tb(max(b, 8), bm, bn, xb.dtype.itemsize)
    pad_b = (-b) % tb
    if pad_b:
        xb = jnp.pad(xb, ((0, pad_b), (0, 0)))
    out = bsr_matmul_pallas(xb, w.blocks, w.blockcol, w.nblocks, tb=tb,
                            interpret=interpret)
    return out[:b, :m].reshape(lead + (m,)).astype(x.dtype)
