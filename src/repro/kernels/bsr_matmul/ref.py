"""Pure-jnp oracle for the BCSR matmul kernel: dense matmul on the
reconstructed dense weight (sparsity must not change semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse_format import BcsrMatrix, bcsr_to_dense


def bsr_matmul_ref(x: jax.Array, b: BcsrMatrix) -> jax.Array:
    """y = x @ W.T in float32, from the dense reconstruction of W."""
    w = bcsr_to_dense(b).astype(jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), w.T,
                      preferred_element_type=jnp.float32)
