"""Pallas TPU kernel: block-sparse (BCSR) matmul on the MXU.

Beyond-paper TPU adaptation of Escoin (DESIGN.md §2): unstructured CSR can
never feed the 128x128 systolic array, so pruning at tile granularity keeps
surviving tiles dense and MXU-eligible while zero tiles are *structurally*
skipped — the TPU-native way to turn weight sparsity into speed.

Mechanics (the canonical scalar-prefetch gather pattern):
  * grid = (batch_tiles, block_rows, KB) with KB innermost so the output block
    stays resident in VMEM and accumulates across the KB steps.
  * the input BlockSpec's index_map reads the scalar-prefetched ``blockcol``
    array, so the pipeline fetches exactly the x tile each nonzero weight tile
    needs — HBM traffic scales with nnz blocks, not with N.
  * rows shorter than KB mask the tail via ``pl.when`` on ``nblocks``; the
    compute (though not the final fetch) is skipped.

Computes y = x @ W.T with W of logical shape (M, N): x tiles are (TB, bn),
weight tiles (bm, bn), out tiles (TB, bm) accumulated in float32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(blockcol_ref, nblocks_ref,   # scalar prefetch (SMEM)
            x_ref, w_ref,                # VMEM in: (TB, bn), (1, 1, bm, bn)
            out_ref):                    # VMEM out: (TB, bm) f32
    i = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(kb < nblocks_ref[i])
    def _accum():
        out_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[0, 0],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def bsr_matmul_pallas(x: jax.Array, blocks: jax.Array, blockcol: jax.Array,
                      nblocks: jax.Array, *, tb: int,
                      interpret: bool = False) -> jax.Array:
    """y = x @ W.T for BCSR W.

    Args:
      x:        (B, N) with B % tb == 0 and N % bn == 0 (ops.py pads).
      blocks:   (gm, KB, bm, bn) dense nonzero tiles.
      blockcol: (gm, KB) int32 block-column ids.
      nblocks:  (gm,) int32 true tiles per block-row.
      tb:       batch tile size.

    Returns: (B, gm*bm) float32.
    """
    b, n = x.shape
    gm, kb_dim, bm, bn = blocks.shape
    assert b % tb == 0 and n % bn == 0, (x.shape, blocks.shape, tb)
    grid = (b // tb, gm, kb_dim)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                # The gather: x's block column follows the weight's blockcol.
                pl.BlockSpec((tb, bn),
                             lambda bt, i, kb, bc, nb: (bt, bc[i, kb])),
                pl.BlockSpec((1, 1, bm, bn),
                             lambda bt, i, kb, bc, nb: (i, kb, 0, 0)),
            ],
            out_specs=pl.BlockSpec((tb, bm),
                                   lambda bt, i, kb, bc, nb: (bt, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, gm * bm), jnp.float32),
        interpret=interpret,
    )(blockcol, nblocks, x, blocks)
