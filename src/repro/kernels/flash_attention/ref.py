"""Pure-jnp oracle for the flash attention kernel: naive softmax attention."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, scale: Optional[float] = None) -> jax.Array:
    """q (B, H, T, d), k/v (B, KV, S, d) -> (B, H, T, d), float32 math."""
    b, h, t, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qf = q.reshape(b, kv, g, t, d).astype(jnp.float32) * scale
    logits = jnp.einsum("bkgtd,bksd->bkgts", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(t)[:, None] >= jnp.arange(s)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", p, v.astype(jnp.float32))
    return out.reshape(b, h, t, d).astype(q.dtype)
