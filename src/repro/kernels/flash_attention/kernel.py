"""Pallas TPU flash attention (fwd + bwd kernels, causal, GQA-aware).

Why it exists here: the dry-run rooflines show the memory term of every
32k-prefill/train cell dominated by XLA materialising the (T, T) attention
logits in float32 HBM.  Keeping the logits tile-resident in VMEM (the flash
schedule) removes that traffic — exactly the paper's locality thesis
("orchestrate on-chip memory so off-chip traffic scales with the data, not
with the algorithm's intermediate"), applied to attention.

Layout: q (B, H, T, d), k/v (B, KV, S, d), GQA via H = KV * G (the kernel
maps head h to kv head h // G in the BlockSpec index maps, so K/V are never
expanded in HBM).  Causal masking skips whole kv-chunks past the q-chunk
(dynamic fori bound), halving the work vs a masked full sweep.

Backward uses the standard recompute formulation:
  P = exp(QK^T * sc - lse);  dV = P^T dO;  dP = dO V^T
  dS = P * (dP - delta),  delta = rowsum(dO * O)
  dQ = dS K * sc;  dK = dS^T Q * sc
split into a dq kernel (grid over q chunks) and a dkv kernel (grid over kv
chunks) so each output block is written by exactly one grid cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                sc: float, causal: bool, cq: int, ck: int, nk: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * sc            # (cq, d)

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(ki * ck, ck), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(ki * ck, ck), :].astype(jnp.float32)
        s = q @ k.T                                     # (cq, ck)
        if causal:
            qpos = qi * cq + lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
            kpos = ki * ck + lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((cq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((cq,), jnp.float32)
    a0 = jnp.zeros((cq, q_ref.shape[-1]), jnp.float32)
    # causal chunk skip: process kv chunks that overlap [0, (qi+1)*cq)
    hi = ((qi + 1) * cq + ck - 1) // ck if causal else nk
    m, l, acc = lax.fori_loop(0, hi, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = m + jnp.log(l)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               sc: float, causal: bool, cq: int, ck: int, nk: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    def body(ki, dq):
        k = k_ref[0, 0, pl.ds(ki * ck, ck), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(ki * ck, ck), :].astype(jnp.float32)
        s = (q * sc) @ k.T
        if causal:
            qpos = qi * cq + lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
            kpos = ki * ck + lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dq + ds @ k

    hi = ((qi + 1) * cq + ck - 1) // ck if causal else nk
    dq0 = jnp.zeros_like(q)
    dq = lax.fori_loop(0, hi, body, dq0)
    dq_ref[0, 0] = (dq * sc).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *,
                sc: float, causal: bool, cq: int, ck: int, nq: int, g: int):
    ki = pl.program_id(2)
    k = k_ref[0, 0].astype(jnp.float32)                 # (ck, d)
    v = v_ref[0, 0].astype(jnp.float32)

    def head_body(gi, carry):
        dk, dv = carry

        def body(qi2, carry2):
            dk2, dv2 = carry2
            q = q_ref[0, gi, pl.ds(qi2 * cq, cq), :].astype(jnp.float32)
            do = do_ref[0, gi, pl.ds(qi2 * cq, cq), :].astype(jnp.float32)
            lse = lse_ref[0, gi, pl.ds(qi2 * cq, cq)]
            delta = delta_ref[0, gi, pl.ds(qi2 * cq, cq)]
            s = (q * sc) @ k.T                          # (cq, ck)
            if causal:
                qpos = qi2 * cq + lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
                kpos = ki * ck + lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
                s = jnp.where(qpos >= kpos, s, NEG_INF)
            p = jnp.exp(s - lse[:, None])
            dv3 = dv2 + p.T @ do
            dp = do @ v.T
            ds = p * (dp - delta[:, None])
            dk3 = dk2 + ds.T @ q
            return dk3, dv3

        lo = ki * ck // cq if causal else 0             # first q chunk that sees us
        dk, dv = lax.fori_loop(lo, nq, body, (dk, dv))
        return dk, dv

    dk0 = jnp.zeros_like(k)
    dv0 = jnp.zeros_like(v)
    dk, dv = lax.fori_loop(0, g, head_body, (dk0, dv0))
    dk_ref[0, 0] = (dk * sc).astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _fwd_call(q, k, v, *, sc, causal, cq, ck, interpret):
    b, h, t, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    nq, nk = t // cq, s // ck
    kern = functools.partial(_fwd_kernel, sc=sc, causal=causal, cq=cq, ck=ck,
                             nk=nk)
    return pl.pallas_call(
        kern,
        grid=(b, h, nq),
        in_specs=[
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, cq), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, t), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _bwd_call(q, k, v, o, lse, do, *, sc, causal, cq, ck, interpret):
    b, h, t, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    nq, nk = t // cq, s // ck
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, sc=sc, causal=causal, cq=cq, ck=ck,
                          nk=nk),
        grid=(b, h, nq),
        in_specs=[
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, cq), lambda bi, hi, qi: (bi, hi, qi)),
            pl.BlockSpec((1, 1, cq), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, sc=sc, causal=causal, cq=cq, ck=ck,
                          nq=nq, g=g),
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, g, t, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bi, ki, si: (bi, ki, si, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bi, ki, si: (bi, ki, si, 0)),
            pl.BlockSpec((1, g, t, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, g, t), lambda bi, ki, si: (bi, ki, 0)),
            pl.BlockSpec((1, g, t), lambda bi, ki, si: (bi, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ck, d), lambda bi, ki, si: (bi, ki, si, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bi, ki, si: (bi, ki, si, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, sc: float, causal: bool, cq: int, ck: int,
                    interpret: bool):
    o, _ = _fwd_call(q, k, v, sc=sc, causal=causal, cq=cq, ck=ck,
                     interpret=interpret)
    return o


def _flash_fwd(q, k, v, sc, causal, cq, ck, interpret):
    o, lse = _fwd_call(q, k, v, sc=sc, causal=causal, cq=cq, ck=ck,
                       interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(sc, causal, cq, ck, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_call(q, k, v, o, lse, do, sc=sc, causal=causal,
                           cq=cq, ck=ck, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
