"""Jit'd wrapper: (B, T, H, hd) model layout -> kernel layout + chunk tuning."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.budget import VMEM_BUDGET as _VMEM_BUDGET


def choose_chunks(t: int, s: int, d: int, itemsize: int):
    """Largest power-of-two chunks with (q + k + v + p) tiles inside VMEM."""
    for c in (1024, 512, 256, 128):
        if t % c or s % c:
            continue
        need = c * d * itemsize * 3 + c * c * 4 + c * d * 4
        if need <= _VMEM_BUDGET:
            return c, c
    return min(128, t), min(128, s)


def flash_attention_bthd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, scale: Optional[float] = None,
                         chunk: Optional[int] = None,
                         interpret: bool = False) -> jax.Array:
    """q (B, T, H, hd), k/v (B, S, KV, hd) -> (B, T, H, hd)."""
    from repro.kernels.flash_attention.kernel import flash_attention
    b, t, h, hd = q.shape
    s = k.shape[1]
    sc = scale if scale is not None else hd ** -0.5
    cq, ck = (chunk, chunk) if chunk else choose_chunks(t, s, hd, q.dtype.itemsize)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), sc, causal, cq, ck, interpret)
    return out.transpose(0, 2, 1, 3)
