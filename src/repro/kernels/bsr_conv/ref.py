"""Pure-jnp oracles for the BCSR MXU conv kernel.

Two references, two jobs:

``bsr_conv_ref``          -- XLA's dense convolution over the dense
                             reconstruction of the blocked bank: block
                             sparsity is a performance transform, not a
                             semantic one, so dense conv defines ground
                             truth (the same contract as the ELL kernel's
                             oracle).
``bsr_conv_blocked_ref``  -- a structural mirror of the kernel's math for
                             the *untiled* spatial schedule: the same
                             per-block patch gather and (bm, bn) x
                             (bn, E, F) f32 ``dot_general``, accumulated in
                             the same KB order, with the same epilogue on
                             the f32 accumulator.  Because interpret-mode
                             Pallas executes the identical op sequence on
                             identical operands, the kernel is *bit-
                             identical* to this mirror — the parity grid's
                             exactness anchor, next to the allclose checks
                             against the dense oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.sparse_format import BcsrConv, bcsr_conv_to_dense


@jax.jit
def _scaled_accum(acc: jax.Array, scale_row: jax.Array,
                  contrib: jax.Array) -> jax.Array:
    """One compiled ``acc + scale * contrib`` step, matching the kernel's
    fused multiply-add rounding (see ``bsr_conv_blocked_ref``)."""
    return acc + scale_row[None, :, None, None] * contrib


def bsr_conv_ref(x: jax.Array, w_dense: jax.Array, *, stride: int = 1,
                 padding: int = 0) -> jax.Array:
    """(N, C, H, W) x (M, C, R, S) -> (N, M, E, F), float32 accumulate."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32), w_dense.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32)


def bsr_conv_blocked_ref(x: jax.Array, bc: BcsrConv, *, stride: int = 1,
                         padding: int = 0,
                         bias: Optional[jax.Array] = None,
                         fuse_relu: bool = False,
                         residual: Optional[jax.Array] = None) -> jax.Array:
    """Mirror the kernel's untiled block contraction in pure jnp.

    Host loops over the static block structure (block-column ids pulled to
    numpy — this is an oracle, not a jit path); the per-block math is the
    kernel's exact op sequence.  Returns (N, M, E, F) float32 in natural
    channel order (the gbm*bm channel padding already sliced off).

    A quantised bank (``bc.scale`` set) mirrors the kernel's in-kernel
    dequantisation exactly: the int8/fp8 tile is contracted as-is in f32
    and each block's contribution is scaled by the per-channel f32 scales
    *before* the accumulate — the same op order as the kernel, so the
    parity grid's bit-identity anchor holds for quantised banks too.  The
    scaled accumulate runs inside one jitted chain (``_scaled_accum``): the
    kernel body is compiled as a whole, so XLA contracts its
    ``acc + scale * contrib`` into a fused multiply-add; op-by-op eager
    execution would round the multiply separately and drift by ~1 ulp.
    """
    n, c, h, w = x.shape
    m, cw, r, s = bc.shape
    rs = r * s
    gbm, kb_dim, bm, bn = bc.blocks.shape
    e = (h + 2 * padding - r) // stride + 1
    f = (w + 2 * padding - s) // stride + 1
    xpad = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    e_ext = (e - 1) * stride + 1
    f_ext = (f - 1) * stride + 1
    blockcol = np.asarray(bc.blockcol)
    nblocks = np.asarray(bc.nblocks)

    def patch_tile(j0: int) -> jax.Array:
        rows = []
        for jl in range(bn):
            j = j0 + jl
            cj = min(j // rs, c - 1)   # inert right-padding columns clamp
            rem = j - (j // rs) * rs
            rr = rem // s
            ss = rem - rr * s
            win = xpad[:, cj, rr:rr + e_ext, ss:ss + f_ext]
            rows.append(win[:, ::stride, ::stride])
        return jnp.stack(rows, axis=1)   # (N, bn, E, F)

    out_rows = []
    for mt in range(gbm):
        acc = jnp.zeros((n, bm, e, f), jnp.float32)
        for kb in range(int(nblocks[mt])):
            patch = patch_tile(int(blockcol[mt, kb]) * bn)
            w_tile = bc.blocks[mt, kb].astype(jnp.float32)
            contrib = jax.vmap(
                lambda p, wt=w_tile: lax.dot_general(
                    wt, p.astype(jnp.float32),
                    dimension_numbers=(((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32))(patch)
            if bc.scale is not None:
                acc = _scaled_accum(acc, bc.scale[mt], contrib)
            else:
                acc = acc + contrib
        if bias is not None:
            b = jnp.asarray(bias, jnp.float32)
            b = jnp.pad(b, (0, gbm * bm - b.shape[0]))
            acc = acc + b[mt * bm:(mt + 1) * bm][None, :, None, None]
        out_rows.append(acc)
    out = jnp.concatenate(out_rows, axis=1)[:, :m]
    if residual is not None:
        out = out + residual.astype(jnp.float32)
    if fuse_relu:
        out = jnp.maximum(out, 0.0)
    return out


def bsr_conv_dense_oracle(x: jax.Array, bc: BcsrConv, *, stride: int = 1,
                          padding: int = 0) -> jax.Array:
    """Dense-reconstruction conv of a blocked bank (convenience wrapper)."""
    return bsr_conv_ref(x, bcsr_conv_to_dense(bc), stride=stride,
                        padding=padding)
