"""Pallas TPU kernel: block-sparse (BCSR) direct convolution on the MXU.

The ELL kernel (``kernels/sparse_conv``) issues one full-width VPU FMA per
nonzero weight — the faithful TPU port of Escoin's per-nonzero GPU threads.
That is the right shape for *very* sparse banks, but moderately-sparse,
large-channel layers (GoogLeNet 1x1s, ResNet bottlenecks) burn VPU issue
slots one scalar weight at a time while the 128x128 systolic array idles.
This kernel trades a little pruning flexibility for dense-unit throughput
(Park et al.'s direct sparse convolution refined with the Balanced-Sparsity
insight of block-structured pruning): weights are pruned at (bm, bn) tile
granularity over the flattened (M, C*R*S) weight matrix
(``core/sparse_format.py:BcsrConv``), surviving tiles stay fully dense, and
each one becomes a single MXU contraction against a gathered input-patch
tile.

Mechanics:

  * grid = (N, ceil(E/TE), ceil(F/TF), gbm, KB) with KB innermost so the
    (bm, TE, TF) f32 output block stays VMEM-resident and accumulates
    across the kept weight tiles of its block-row (the ``bsr_matmul``
    accumulation pattern, spatially tiled).
  * the halo'd (C, halo_h, halo_w) input block for one spatial cell is
    DMA'd HBM->VMEM once — at the cell's first (mt, kb) step — and reused
    by every weight tile of every block-row of that cell (the ELL kernel's
    staging discipline; overlapping halo blocks cannot be expressed with
    blocked BlockSpecs, so the input stays in ``ANY`` and the kernel issues
    an explicit sliced copy).
  * per kept tile, the *gather* stage decodes each of the tile's bn flat
    weight columns ``j = blockcol*bn + jl`` into ``(c, r, s)`` (two static
    divmods — the same index arithmetic weight stretching trades bytes for)
    and writes the strided (TE, TF) input window into row ``jl`` of a
    (bn, TE, TF) VMEM patch buffer: an im2col patch tile, built on-chip
    from the staged halo block instead of materialised in HBM (the
    bandwidth waste the paper's direct method exists to remove).
  * the *contract* stage is one ``dot_general`` of the (bm, bn) weight tile
    against the (bn, TE, TF) patch tile with f32 accumulation — MXU work.
    The gather is VPU work; the autotuner's roofline prices exactly this
    gather-vs-systolic tradeoff (``tuning/measure.py:_bsr_terms``).
  * rows shorter than KB mask the tail via ``pl.when`` on ``nblocks``;
    block-columns past C*R*S (format right-padding) clamp their channel
    decode — their weights are zero, so the clamped reads are inert.
  * the fused epilogue (per-channel bias, optional residual, static ReLU)
    runs on the resident f32 accumulator at the last KB step — one output
    write, exactly like the ELL kernel's epilogue.

Strides and edge tiles follow the ELL kernel: dynamic-start windows with a
static ``[::stride]`` slice, ceiling-division spatial grids with masked
out-of-range writes, and input zero-padding so every halo window stays in
bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(blockcol_ref, nblocks_ref,   # scalar prefetch (SMEM)
            x_ref,                       # HBM/ANY: halo-padded input
            w_ref,                       # VMEM in: (1, 1, bm, bn)
            b_ref,                       # VMEM in: (1, bm) f32 bias
            *rest,                       # [scale_ref,] [res_ref,] out_ref,
                                         # xblk, patch, sem
            bm: int, bn: int, rs: int, s: int, c_in: int, stride: int,
            te: int, tf: int, halo_h: int, halo_w: int,
            fuse_relu: bool, has_res: bool, quantized: bool):
    rest = list(rest)
    scale_ref = rest.pop(0) if quantized else None
    if has_res:
        res_ref, out_ref, xblk_ref, patch_ref, sem = rest
    else:
        res_ref = None
        out_ref, xblk_ref, patch_ref, sem = rest
    ni = pl.program_id(0)
    et = pl.program_id(1)
    ft = pl.program_id(2)
    mt = pl.program_id(3)
    kb = pl.program_id(4)
    kb_n = pl.num_programs(4)

    # Stage the halo'd input block once per (image, spatial tile); the
    # (mt, kb) dims are innermost, so it persists for every weight tile of
    # this cell (TPU grids run sequentially).
    @pl.when(jnp.logical_and(mt == 0, kb == 0))
    def _stage():
        dma = pltpu.make_async_copy(
            x_ref.at[ni, :, pl.ds(et * te * stride, halo_h),
                     pl.ds(ft * tf * stride, halo_w)],
            xblk_ref, sem)
        dma.start()
        dma.wait()

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Dynamic-start window extent for a static [::stride] landing exactly on
    # the TE (resp. TF) output positions of this tile.
    e_ext = (te - 1) * stride + 1
    f_ext = (tf - 1) * stride + 1

    @pl.when(kb < nblocks_ref[mt])
    def _accum():
        j0 = blockcol_ref[mt, kb] * bn
        # Gather (VPU): build the (bn, TE, TF) im2col patch tile for this
        # block column from the staged halo block, one decoded weight
        # column per row.  jl is static (unrolled), j0 is a prefetched
        # scalar.
        for jl in range(bn):
            j = j0 + jl
            cj = j // rs
            rem = j - cj * rs
            r = rem // s
            ss = rem - r * s
            # Right-padding columns (j >= C*R*S) carry zero weights; clamp
            # the channel so their gather stays in bounds (value is inert).
            cj = jnp.minimum(cj, c_in - 1)
            win = xblk_ref[cj, pl.ds(r, e_ext), pl.ds(ss, f_ext)]
            patch_ref[jl] = win[::stride, ::stride]
        # Contract (MXU): one (bm, bn) x (bn, TE*TF) systolic pass, f32
        # accumulate into the resident output block.
        contrib = lax.dot_general(
            w_ref[0, 0].astype(jnp.float32),
            patch_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if quantized:
            # Dequantise after the contraction: the int8/fp8 tile is
            # contracted as-is in f32 and each output row's contribution is
            # scaled by its per-channel f32 scale before accumulating —
            # accumulation stays f32 throughout.
            contrib = scale_ref[0][:, None, None] * contrib
        out_ref[0] += contrib

    # Fused epilogue on the resident f32 accumulator at the last KB step:
    # one output write instead of separate bias / residual / ReLU passes.
    @pl.when(kb == kb_n - 1)
    def _epilogue():
        acc = out_ref[0] + b_ref[0][:, None, None]
        if has_res:
            acc = acc + res_ref[0].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        out_ref[0] = acc


@functools.partial(
    jax.jit,
    static_argnames=("rs", "s", "e", "f", "stride", "te", "tf",
                     "fuse_relu", "interpret"))
def bsr_conv_pallas(xpad: jax.Array, blocks: jax.Array, blockcol: jax.Array,
                    nblocks: jax.Array, bias: jax.Array,
                    residual: jax.Array | None = None,
                    scale: jax.Array | None = None, *, rs: int, s: int,
                    e: int, f: int, stride: int = 1, te: int | None = None,
                    tf: int | None = None, fuse_relu: bool = False,
                    interpret: bool = False) -> jax.Array:
    """Launch the BCSR MXU conv kernel.

    Args:
      xpad:     (N, C, Hp, Wp) pre-padded input (the paper's pad_in step).
      blocks:   (gbm, KB, bm, bn) kept weight tiles (``BcsrConv.blocks``) —
                f32, or int8/fp8 for a quantised bank (``scale`` required).
      blockcol: (gbm, KB) int32 block-column ids over the flat C*R*S axis.
      nblocks:  (gbm,) int32 true tiles per block-row.
      bias:     (gbm, bm) f32 per-channel bias, blocked like the output
                channels (pass zeros for a bias-free conv — bitwise no-op).
      residual: optional (N, gbm*bm, E, F) shortcut accumulated before the
                ReLU, channel-padded like the output.
      scale:    optional (gbm, bm) f32 per-output-channel quantisation
                scales, blocked like the bias; each weight tile's post-MXU
                contribution is scaled by its rows' scales before the f32
                accumulate.
      rs, s:    R*S and S of the original filter bank (column decode).
      e, f:     output spatial dims; stride applied in-kernel.
      te, tf:   output spatial tile dims (default: whole output).  Need not
                divide e/f — edge tiles use ceiling-division grids + masked
                writes.
      fuse_relu: clamp the accumulator in-kernel (the fused epilogue).

    Returns: (N, gbm*bm, E, F) float32 — callers slice to the true M.
    """
    n, c, hp, wp = xpad.shape
    gbm, kb_dim, bm, bn = blocks.shape
    te = e if te is None else min(te, e)
    tf = f if tf is None else min(tf, f)
    r = rs // s
    halo_h = (te - 1) * stride + r
    halo_w = (tf - 1) * stride + s
    et_n = pl.cdiv(e, te)
    ft_n = pl.cdiv(f, tf)
    # Zero-pad so the *last* tile's halo window stays in bounds; the extra
    # rows/cols only ever feed output positions >= E/F, which Pallas drops.
    need_h = (et_n * te - 1) * stride + r
    need_w = (ft_n * tf - 1) * stride + s
    if need_h > hp or need_w > wp:
        xpad = jnp.pad(xpad, ((0, 0), (0, 0), (0, max(0, need_h - hp)),
                              (0, max(0, need_w - wp))))
    grid = (n, et_n, ft_n, gbm, kb_dim)
    has_res = residual is not None
    quantized = scale is not None
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((1, 1, bm, bn), lambda ni, et, ft, mt, kb, *_: (mt, kb, 0, 0)),
        pl.BlockSpec((1, bm), lambda ni, et, ft, mt, kb, *_: (mt, 0)),
    ]
    inputs = [blockcol, nblocks, xpad, blocks, bias]
    if quantized:
        in_specs.append(pl.BlockSpec(
            (1, bm), lambda ni, et, ft, mt, kb, *_: (mt, 0)))
        inputs.append(scale)
    if has_res:
        in_specs.append(pl.BlockSpec(
            (1, bm, te, tf), lambda ni, et, ft, mt, kb, *_: (ni, mt, et, ft)))
        inputs.append(residual)
    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, bn=bn, rs=rs, s=s, c_in=c,
                          stride=stride, te=te, tf=tf, halo_h=halo_h,
                          halo_w=halo_w, fuse_relu=fuse_relu, has_res=has_res,
                          quantized=quantized),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (1, bm, te, tf),
                lambda ni, et, ft, mt, kb, *_: (ni, mt, et, ft)),
            scratch_shapes=[
                pltpu.VMEM((c, halo_h, halo_w), xpad.dtype),
                pltpu.VMEM((bn, te, tf), xpad.dtype),
                pltpu.SemaphoreType.DMA,
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n, gbm * bm, e, f), jnp.float32),
        interpret=interpret,
    )(*inputs)
