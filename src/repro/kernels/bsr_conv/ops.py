"""Jit'd public wrapper around the BCSR MXU conv kernel.

Handles: input padding (pad_in), output spatial tile selection (te, tf) with
the halo'd-block VMEM feasibility model, channel padding (the format blocks
M up to gbm*bm — bias and residual are padded in, the output sliced back),
the dtype policy (bf16/f32 in, f32 accumulate, cast back on exit), the fused
epilogue (bias / ReLU / bottleneck residual on the f32 accumulator,
one output write), and the fallback to the dense-reconstruction conv — with
the identical epilogue applied unfused — for geometries whose block table
busts the SMEM budget or for which no VMEM-feasible spatial tiling exists.

The block shape (bm, bn) is the format's, fixed at ``bcsr_conv_from_dense``
time; the wrapper's tunable axes are the spatial tiles, which the
``repro.tuning`` autotuner turns alongside the block-size candidates.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.direct_conv import out_spatial
from repro.core.sparse_format import BcsrConv, bcsr_conv_to_dense
from repro.kernels import budget
from repro.kernels.budget import (SMEM_BUDGET, VMEM_BUDGET, halo_extent,
                                  value_itemsize)
from repro.kernels.bsr_conv.kernel import bsr_conv_pallas
from repro.kernels.bsr_conv.ref import bsr_conv_ref
from repro.kernels.sparse_conv.ops import apply_epilogue, spatial_candidates
from repro.telemetry.fallback import record_fallback

# The candidate (bm, bn) block shapes the autotuner enumerates: bn pinned to
# the 128-lane MXU width, bm laddered — bigger bm amortises the per-block
# patch gather over more systolic rows (the gather-vs-compute tradeoff the
# roofline prices), smaller bm wastes less on channel padding.
BLOCK_CANDIDATES = ((8, 128), (16, 128), (32, 128), (64, 128))


def bsr_smem_fits(gbm: int, kb: int) -> bool:
    """Both scalar-prefetched operands fit SMEM: the int32 block-column
    table (gbm*KB) and the int32 nblocks row (gbm).  Formula lives in
    ``repro.kernels.budget``; the module-level ``SMEM_BUDGET`` alias is the
    (monkeypatchable) budget this wrapper passes through."""
    return budget.bsr_smem_fits(gbm, kb, smem_budget=SMEM_BUDGET)


def bsr_tiling_fits(c: int, r: int, s: int, stride: int, bm: int, bn: int,
                    te: int, tf: int, itemsize: int = 4,
                    fuse_res: bool = False,
                    value_itemsize: Optional[int] = None,
                    quantized: bool = False) -> bool:
    """Whether one (te, tf) spatial tiling's working set — halo'd input
    block + (bm, bn) weight tile + (bn, te, tf) patch tile + f32 out tile
    (+ the residual input tile when fused, + the (1, bm) f32 scale tile for
    a quantised bank) — fits the VMEM budget (``repro.kernels.budget``
    arithmetic, this module's budget alias).  ``value_itemsize`` prices the
    weight tile at its storage width (defaults to the input itemsize)."""
    return budget.bsr_tiling_fits(c, r, s, stride, bm, bn, te, tf,
                                  itemsize=itemsize, fuse_res=fuse_res,
                                  value_itemsize=value_itemsize,
                                  quantized=quantized,
                                  vmem_budget=VMEM_BUDGET)


def bsr_tile_candidates(c: int, e: int, f: int, r: int, s: int, stride: int,
                        bm: int, bn: int, itemsize: int = 4,
                        fuse_res: bool = False,
                        value_itemsize: Optional[int] = None,
                        quantized: bool = False) -> List[Tuple[int, int]]:
    """All (te, tf) spatial tilings whose VMEM working set fits, preferred
    first: fewest spatial cells (least halo re-fetch and least per-cell
    patch re-gather), then least total staged input traffic."""
    out: List[Tuple[int, int]] = []
    for te in spatial_candidates(e):
        for tf in spatial_candidates(f):
            if bsr_tiling_fits(c, r, s, stride, bm, bn, te, tf,
                               itemsize=itemsize, fuse_res=fuse_res,
                               value_itemsize=value_itemsize,
                               quantized=quantized):
                out.append((te, tf))

    def pref(cand: Tuple[int, int]) -> Tuple[int, int]:
        te, tf = cand
        cells = -(-e // te) * (-(-f // tf))
        staged = cells * c * halo_extent(te, stride, r) * halo_extent(tf, stride, s)
        return (cells, staged)

    return sorted(out, key=pref)


def resolve_bsr_schedule(c: int, e: int, f: int, r: int, s: int, stride: int,
                         bm: int, bn: int, gbm: int, kb: int, *,
                         itemsize: int = 4, te: Optional[int] = None,
                         tf: Optional[int] = None, fuse_res: bool = False,
                         value_dtype: str = "float32",
                         ) -> Tuple[Optional[Tuple[int, int]],
                                    Optional[str]]:
    """The dispatch decision ``bsr_conv`` makes, as a pure function.

    Returns ``((te, tf), None)`` for the spatial tiling the MXU kernel
    would run, or ``(None, reason)`` — a ``telemetry.fallback`` reason
    code — when the layer falls back to the dense-reconstruction conv.
    The engine's ExecutionReport and the benchmark's zero-fallback
    invariant probe dispatch through this; ``bsr_conv`` runs it too.

    ``value_dtype`` names the bank's storage dtype: a quantised bank
    (int8 / float8_e4m3fn) shrinks the VMEM weight tile to one byte per
    element but streams an extra (1, bm) f32 scale tile — both accounted
    here so feasibility matches what the kernel would allocate.
    """
    vsize = value_itemsize(value_dtype)
    quantized = vsize == 1
    if not bsr_smem_fits(gbm, kb):
        return None, "smem_infeasible"
    if te is not None and tf is not None:
        # Fully-specified tiling (tuned plan / caller override): honor it
        # when it fits, never launch an over-budget kernel.
        te, tf = min(te, e), min(tf, f)
        if not bsr_tiling_fits(c, r, s, stride, bm, bn, te, tf,
                               itemsize=itemsize, fuse_res=fuse_res,
                               value_itemsize=vsize, quantized=quantized):
            return None, "no_feasible_tiling"
    else:
        cands = bsr_tile_candidates(c, e, f, r, s, stride, bm, bn,
                                    itemsize=itemsize, fuse_res=fuse_res,
                                    value_itemsize=vsize,
                                    quantized=quantized)
        if te is not None:
            cands = [t for t in cands if t[0] == min(te, e)]
        if tf is not None:
            cands = [t for t in cands if t[1] == min(tf, f)]
        if not cands:
            return None, "no_feasible_tiling"
        te, tf = cands[0]
    return (te, tf), None


def bsr_conv(x: jax.Array, bc: BcsrConv, *, stride: int = 1,
             padding: int = 0, te: Optional[int] = None,
             tf: Optional[int] = None, bias: Optional[jax.Array] = None,
             fuse_relu: bool = False, residual: Optional[jax.Array] = None,
             interpret: bool = False,
             layer: Optional[str] = None) -> jax.Array:
    """Block-sparse convolution + fused epilogue on the MXU.

    (N, C, H, W) input, BCSR filter bank for (M, C, R, S) weights ->
    (N, M, E, F) in x.dtype.  Any stride >= 1 runs in-kernel; te/tf default
    to the preferred feasible spatial tiling and are the knobs the
    ``repro.tuning`` autotuner turns (together with the format's block
    shape).  Falls back to the dense-reconstruction conv — with the
    identical epilogue applied unfused — when the block-column table busts
    SMEM or no spatial tiling fits VMEM, so ``bsr_conv`` is a complete
    conv+epilogue operator either way; any such fallback is reported
    through ``telemetry.record_fallback`` (one-time warning + gated
    counters), ``layer`` naming the conv op when the caller knows it.
    """
    m, c, r, s = bc.shape
    gbm, kb_dim, bm, bn = bc.blocks.shape
    n, _, h, w = x.shape
    e, f = out_spatial(h, w, r, s, stride, padding)
    fuse_res = residual is not None
    itemsize = jnp.dtype(x.dtype).itemsize

    def fallback(reason: str) -> jax.Array:
        record_fallback(
            "bsr_conv", reason, layer=layer,
            geometry=(f"m={m} c={c} e={e} f={f} bm={bm} bn={bn} gbm={gbm} "
                      f"kb={kb_dim} r={r} s={s} stride={stride}"),
            fallback_to="dense")
        y = bsr_conv_ref(x, bcsr_conv_to_dense(bc), stride=stride,
                         padding=padding).astype(x.dtype)
        return apply_epilogue(y, bias, fuse_relu, residual)

    sched, reason = resolve_bsr_schedule(c, e, f, r, s, stride, bm, bn,
                                         gbm, kb_dim, itemsize=itemsize,
                                         te=te, tf=tf, fuse_res=fuse_res,
                                         value_dtype=bc.value_dtype)
    if sched is None:
        return fallback(reason)
    te, tf = sched
    xpad = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    # Channel padding: the kernel computes gbm*bm output channels; bias and
    # residual are padded to match, the result sliced back to M.
    mpad = gbm * bm
    b = (jnp.zeros((m,), jnp.float32) if bias is None
         else jnp.asarray(bias, jnp.float32))
    b = jnp.pad(b, (0, mpad - m)).reshape(gbm, bm)
    res = residual
    if res is not None and mpad != m:
        res = jnp.pad(res, ((0, 0), (0, mpad - m), (0, 0), (0, 0)))
    out = bsr_conv_pallas(
        xpad, bc.blocks, bc.blockcol, bc.nblocks, b, res, scale=bc.scale,
        rs=r * s, s=s, e=e, f=f, stride=stride, te=te, tf=tf,
        fuse_relu=fuse_relu, interpret=interpret)
    return out[:, :m].astype(x.dtype)
