"""Architecture registry: ``--arch <id>`` resolution for launchers/tests.

Each config module exposes ARCH_ID, FAMILY, SHAPES (the applicable input-shape
cells per the DESIGN.md skip table), full() and smoke().
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.models.config import ALL_SHAPES, ModelConfig, ShapeConfig

_MODULES = (
    "deepseek_v3_671b",
    "olmoe_1b_7b",
    "jamba_1_5_large_398b",
    "qwen1_5_0_5b",
    "qwen1_5_4b",
    "mistral_large_123b",
    "yi_9b",
    "hubert_xlarge",
    "mamba2_2_7b",
    "phi_3_vision_4_2b",
)

REGISTRY: Dict[str, object] = {}
for _m in _MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    REGISTRY[mod.ARCH_ID] = mod

SHAPE_BY_NAME = {s.name: s for s in ALL_SHAPES}


def list_archs() -> List[str]:
    return list(REGISTRY.keys())


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = REGISTRY[arch]
    return mod.smoke() if smoke else mod.full()


def applicable_shapes(arch: str) -> List[ShapeConfig]:
    return [SHAPE_BY_NAME[n] for n in REGISTRY[arch].SHAPES]


def skipped_shapes(arch: str) -> List[Tuple[str, str]]:
    """(shape, reason) for every cell the DESIGN.md table skips."""
    mod = REGISTRY[arch]
    out = []
    for s in ALL_SHAPES:
        if s.name in mod.SHAPES:
            continue
        if mod.FAMILY == "encoder":
            out.append((s.name, "encoder-only: no decode step"))
        else:
            out.append((s.name, "full attention: O(T^2), long_500k skipped"))
    return out


def all_cells(*, include_skipped: bool = False):
    """Iterate (arch, shape) cells in registry order."""
    for arch in list_archs():
        for s in applicable_shapes(arch):
            yield arch, s
        if include_skipped:
            for name, reason in skipped_shapes(arch):
                yield arch, SHAPE_BY_NAME[name]
