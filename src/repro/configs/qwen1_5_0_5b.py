"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, QKV bias.
24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936."""
from repro.models.config import ModelConfig

ARCH_ID = "qwen1.5-0.5b"
FAMILY = "dense"
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=24, d_model=1024, vocab=151936,
        n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=2816, qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, qkv_bias=True,
    )
