"""Mistral-Large 123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
— dense GQA.  88L d_model=12288 96H (kv=8) d_ff=28672 vocab=32768."""
from repro.models.config import ModelConfig

ARCH_ID = "mistral-large-123b"
FAMILY = "dense"
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=88, d_model=12288, vocab=32768,
        n_heads=96, n_kv_heads=8, head_dim=128,
        d_ff=28672,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=96, vocab=512,
        n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=192,
    )
