"""Phi-3-Vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct; hf] —
phi3-mini backbone + CLIP frontend (STUB: input_specs() provides precomputed
patch embeddings).  32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064."""
from repro.models.config import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"
FAMILY = "vlm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=32, d_model=3072, vocab=32064,
        n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128,
    )
