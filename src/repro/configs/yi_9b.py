"""Yi-9B [arXiv:2403.04652; hf] — llama-arch GQA.
48L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000."""
from repro.models.config import ModelConfig

ARCH_ID = "yi-9b"
FAMILY = "dense"
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=48, d_model=4096, vocab=64000,
        n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128,
    )
