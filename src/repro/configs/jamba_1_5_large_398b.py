"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] — Mamba+attention 1:7
interleave, MoE 16e top-2.  72L d_model=8192 64H (kv=8) d_ff=24576
vocab=65536.  Hybrid -> sub-quadratic -> long_500k runs."""
from repro.models.config import ModelConfig

ARCH_ID = "jamba-1.5-large-398b"
FAMILY = "hybrid"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=72, d_model=8192, vocab=65536,
        n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, n_experts=16, top_k=2, moe_d_ff=24576, moe_period=2,
        attn_period=8,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=8, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, n_experts=4, top_k=2, moe_d_ff=64, moe_period=2,
        attn_period=4,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    )
