"""HuBERT-XLarge [arXiv:2106.07447; unverified] — encoder-only audio
transformer (w2v2 arch).  48L d_model=1280 16H d_ff=5120 vocab=504.
Modality frontend (conv feature extractor) is a STUB: input_specs() provides
precomputed frame embeddings.  Encoder-only -> no decode shapes."""
from repro.models.config import ModelConfig

ARCH_ID = "hubert-xlarge"
FAMILY = "encoder"
SHAPES = ("train_4k", "prefill_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=48, d_model=1280, vocab=504,
        n_heads=16, n_kv_heads=16, head_dim=80,
        d_ff=5120, mlp_act="gelu", causal=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, mlp_act="gelu", causal=False,
    )
