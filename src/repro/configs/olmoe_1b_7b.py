"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64 experts top-8.
16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304."""
from repro.models.config import ModelConfig

ARCH_ID = "olmoe-1b-7b"
FAMILY = "moe"
SHAPES = ("train_4k", "prefill_32k", "decode_32k")  # full attn -> no long_500k


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=16, d_model=2048, vocab=50304,
        n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1024, n_experts=64, top_k=8, moe_d_ff=1024,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=96, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=128, n_experts=8, top_k=2, moe_d_ff=64,
    )
