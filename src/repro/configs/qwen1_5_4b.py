"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B] — dense, QKV bias.
40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
NOTE: 20 heads do not divide the 16-way model axis; attention activations
replicate over tp while FFN/vocab shard (DESIGN.md §5)."""
from repro.models.config import ModelConfig

ARCH_ID = "qwen1.5-4b"
FAMILY = "dense"
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=40, d_model=2560, vocab=151936,
        n_heads=20, n_kv_heads=20, head_dim=128,
        d_ff=6912, qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=3, d_model=80, vocab=512,
        n_heads=5, n_kv_heads=5, head_dim=16,
        d_ff=128, qkv_bias=True,
    )
