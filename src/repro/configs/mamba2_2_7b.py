"""Mamba2-2.7B [arXiv:2405.21060; unverified] — SSD (state-space duality),
attention-free.  64L d_model=2560 ssm_state=128 vocab=50280.
Sub-quadratic -> all four shapes including long_500k."""
from repro.models.config import ModelConfig

ARCH_ID = "mamba2-2.7b"
FAMILY = "ssm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=64, d_model=2560, vocab=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=4, d_model=64, vocab=512,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
        tie_embeddings=True,
    )
