"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed
top-8 experts, MTP.  61L d_model=7168 128H d_ff(expert)=2048 vocab=129280."""
from repro.models.config import ModelConfig

ARCH_ID = "deepseek-v3-671b"
FAMILY = "moe"
# full attention (MLA is O(T^2)) -> long_500k skipped (DESIGN.md skip table)
SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family=FAMILY,
        n_layers=61, d_model=7168, vocab=129280,
        n_heads=128, n_kv_heads=128, head_dim=128,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
        d_ff=18432,                      # dense FFN in the 3 leading layers
        n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
        first_dense_layers=3, mtp_depth=1,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family=FAMILY,
        n_layers=4, d_model=128, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=32,
        use_mla=True, q_lora_rank=64, kv_lora_rank=32,
        qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32,
        d_ff=256, n_experts=8, top_k=2, moe_d_ff=64, n_shared_experts=1,
        first_dense_layers=1, mtp_depth=1,
    )
