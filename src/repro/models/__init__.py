"""Model zoo: the transformer stack for the 10 assigned architectures
(transformer.py + layers.py + config.py) and the paper's CNNs (cnn.py)."""
