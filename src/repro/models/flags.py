"""Lowering-mode flags shared by layers.py / transformer.py.

These are launcher-controlled globals (not ModelConfig fields) so the same
model code can be re-lowered under different analysis / perf modes:

  REMAT    -- activation-checkpoint policy for the scanned stack.
  UNROLL   -- unroll every loop (stack scan, attention chunk scans, SSD chunk
              scan).  Used by the roofline *probe* compiles: XLA's
              HloCostAnalysis counts a while-loop body once regardless of
              trip count, so probes lower shallow fully-unrolled models and
              the dry-run extrapolates exact per-block costs.
  ATTN_CHUNK -- q/kv chunk size for the online-softmax attention.
"""
from __future__ import annotations

REMAT = "none"
UNROLL = False
ATTN_CHUNK = 1024
MOE_CAPACITY = 1.25   # expert capacity factor (drops above); perf/memory knob
ATTN_IMPL = "chunked"  # chunked (jnp online softmax) | flash (Pallas kernel)
MOE_CONSTRAIN = False  # explicit sharding constraints on MoE dispatch buffers
MOE_IMPL = "gather"    # gather (auto-SPMD) | ep (all-to-all expert parallel)


def set_moe_impl(impl: str) -> None:
    global MOE_IMPL
    assert impl in ("gather", "ep"), impl
    MOE_IMPL = impl


def set_attn_impl(impl: str) -> None:
    global ATTN_IMPL
    assert impl in ("chunked", "flash"), impl
    ATTN_IMPL = impl


def set_moe_constrain(flag: bool) -> None:
    global MOE_CONSTRAIN
    MOE_CONSTRAIN = bool(flag)


def set_moe_capacity(f: float) -> None:
    global MOE_CAPACITY
    MOE_CAPACITY = float(f)


def set_remat(policy: str) -> None:
    global REMAT
    assert policy in ("none", "dots", "full"), policy
    REMAT = policy


def set_unroll(flag: bool) -> None:
    global UNROLL
    UNROLL = bool(flag)


def set_attn_chunk(n: int) -> None:
    global ATTN_CHUNK
    ATTN_CHUNK = int(n)
