"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

§Perf hillclimb (deepseek x train_4k): the auto-SPMD gather dispatch
replicates the (E*C, D) slot buffer across the model axis (all-gather fwd,
all-reduce of scatter-adds bwd) — ~10 TB/device/step.  Real EP systems
(DeepSeek included) move tokens with an all-to-all whose volume is the
activation bytes x top_k, independent of the expert count.  This module is
that implementation:

  inside shard_map over the model axis (tp ranks own E/tp experts each):
    1. route locally: top-k experts per local token;
    2. bucket tokens by destination rank into fixed-capacity send buffers
       (capacity = local_tokens * k / tp * factor; overflow drops, exactly
       like the capacity semantics of the baseline path);
    3. lax.all_to_all the (tp, cap, D) buffer;
    4. locally group received tokens by local expert (second-level capacity
       buffers), run the expert FFN;
    5. all_to_all back and combine with router weights.

Everything is gathers/sorts/all_to_all — all differentiable; backward is the
mirrored all-to-all (same volume), not a replicated scatter-add.

The data/pod axes stay on auto SPMD (partial shard_map), so the same code
serves every mesh.  Weights enter the shard_map already sharded: experts
over tp (manual axis), d_model over fsdp (auto).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import flags as F
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _bucket_by(dest: jax.Array, n_buckets: int, capacity: int):
    """dest: (N,) int32 bucket ids -> (slot (N,), token_for_slot (n_buckets*cap,)).

    slot[i] = global slot of item i (bucket*cap + pos) or sentinel when the
    bucket overflows; token_for_slot inverts (sentinel N for empty slots).
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_d = dest[order]
    start = jnp.searchsorted(sorted_d, jnp.arange(n_buckets), side="left")
    pos = jnp.arange(n) - start[sorted_d]
    ok = pos < capacity
    slot_sorted = jnp.where(ok, sorted_d * capacity + pos, n_buckets * capacity)
    slot = jnp.zeros((n,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    token_for_slot = jnp.full((n_buckets * capacity + 1,), n, jnp.int32
                              ).at[slot_sorted].set(order.astype(jnp.int32),
                                                    mode="drop")
    return slot, token_for_slot[:-1]


def _ep_local(p: Params, xg: jax.Array, cfg: ModelConfig, *, ax: str,
              tp: int, cap_rank: int, cap_exp: int) -> jax.Array:
    """Runs on each model-axis rank. xg: (n_loc, D) local tokens."""
    n, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // tp

    logits = jnp.einsum("gd,de->ge", xg.astype(jnp.float32), p["router"])
    topw, topi = lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    topw = (topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9))

    flat_e = topi.reshape(-1).astype(jnp.int32)          # (n*k,)
    dest_rank = flat_e // e_loc
    slot, tok4slot = _bucket_by(dest_rank, tp, cap_rank)
    xpad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], 0)
    send = xpad[jnp.minimum(tok4slot // k, n)].reshape(tp, cap_rank, d)
    send = jnp.where((tok4slot < n * k).reshape(tp, cap_rank, 1), send, 0)
    # also ship the target (local) expert id per slot
    send_eid = jnp.where(tok4slot < n * k, flat_e[jnp.minimum(tok4slot, n * k - 1)],
                         -1).reshape(tp, cap_rank)

    recv = lax.all_to_all(send, ax, split_axis=0, concat_axis=0, tiled=False)
    recv_eid = lax.all_to_all(send_eid, ax, split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(tp * cap_rank, d)
    loc_eid = jnp.where(recv_eid.reshape(-1) >= 0,
                        recv_eid.reshape(-1) % e_loc, e_loc)  # sentinel bucket

    # second-level grouping: received tokens -> local expert capacity buffers
    slot2, tok4slot2 = _bucket_by(loc_eid.astype(jnp.int32), e_loc, cap_exp)
    rpad = jnp.concatenate([recv, jnp.zeros((1, d), recv.dtype)], 0)
    xe = rpad[jnp.minimum(tok4slot2, tp * cap_rank)].reshape(e_loc, cap_exp, d)

    hg = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"],
                    preferred_element_type=jnp.float32)
    hu = jnp.einsum("ecd,edf->ecf", xe, p["w_up"],
                    preferred_element_type=jnp.float32)
    hy = (jax.nn.silu(hg) * hu).astype(xe.dtype)
    y = jnp.einsum("ecf,efd->ecd", hy, p["w_down"],
                   preferred_element_type=jnp.float32).astype(xe.dtype)

    # invert level 2: per received slot
    ypad = jnp.concatenate([y.reshape(e_loc * cap_exp, d),
                            jnp.zeros((1, d), y.dtype)], 0)
    y_recv = ypad[jnp.minimum(slot2, e_loc * cap_exp)]     # (tp*cap_rank, d)
    y_recv = y_recv.reshape(tp, cap_rank, d)
    # return trip
    y_send = lax.all_to_all(y_recv, ax, split_axis=0, concat_axis=0,
                            tiled=False).reshape(tp * cap_rank, d)
    # invert level 1: per (token, k)
    ypad1 = jnp.concatenate([y_send, jnp.zeros((1, d), y_send.dtype)], 0)
    per_k = ypad1[jnp.minimum(slot, tp * cap_rank)].reshape(n, k, d)
    out = jnp.einsum("gk,gkd->gd", topw.astype(jnp.float32),
                     per_k.astype(jnp.float32)).astype(xg.dtype)
    return out


def moe_fwd_ep(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Drop-in for layers.moe_fwd using all-to-all expert parallelism.

    Requires an active mesh whose tp axis divides n_experts; otherwise the
    caller should use the auto-SPMD path.
    """
    from repro.distributed import sharding as shd
    from repro.models.layers import mlp_fwd

    mesh = shd.get_mesh()
    rules = shd.get_rules() or {}
    ax = rules.get("tp")
    assert mesh is not None and ax in mesh.axis_names
    tp = mesh.shape[ax]
    assert cfg.n_experts % tp == 0
    dp_axes = tuple(a for a in mesh.axis_names if a != ax)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]

    b, t, d = x.shape
    cf = F.MOE_CAPACITY
    # fully-manual shard_map: batch local to the dp shards, seq local to tp,
    # so every sort/gather is device-local and the only cross-device traffic
    # is the two all_to_alls (+ the usual FSDP weight gather at the boundary).
    n_loc = max(1, b * t // (dp_total * tp))
    cap_rank = max(8, int(n_loc * cfg.top_k / tp * cf) // 8 * 8)
    cap_exp = max(8, int(tp * cap_rank / (cfg.n_experts // tp) * cf) // 8 * 8)

    router = p["router"]
    experts = {k2: p[k2] for k2 in ("w_gate", "w_up", "w_down")}
    batch_spec = dp_axes if b % dp_total == 0 else None
    seq_spec = ax if t % tp == 0 else None

    def local(router_l, experts_l, x_l):
        bl, tl, _ = x_l.shape
        flat = x_l.reshape(bl * tl, d)
        pl = dict(experts_l)
        pl["router"] = router_l
        out = _ep_local(pl, flat, cfg, ax=ax, tp=tp,
                        cap_rank=cap_rank, cap_exp=cap_exp)
        return out.reshape(bl, tl, d)

    out = jax.shard_map(
        local,
        mesh=mesh,
        # router replicated; experts sharded over tp, gathered over fsdp at
        # the boundary (exactly the FSDP all-gather auto-SPMD would insert)
        in_specs=(P(), P(ax, None, None), P(batch_spec, seq_spec, None)),
        out_specs=P(batch_spec, seq_spec, None),
        check_vma=False,
    )(router, experts, x)
    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], x, "swiglu")
    return out
