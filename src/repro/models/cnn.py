"""The paper's CNN benchmark models: AlexNet, GoogLeNet (v1), ResNet-50.

Each network is a table of layer specs; convolutions execute through a
selectable method (paper Table/Figs 8-11):

  "dense"      -- XLA dense conv on zero-filled weights   (CUBLAS analogue)
  "lowered"    -- im2col + ELL(CSR) SpMM                  (CUSPARSE analogue)
  "csr-direct" -- Escoin direct sparse conv, pure-JAX scan
  "pallas"     -- Escoin direct sparse conv, Pallas kernel (interpret on CPU)
                  with the bias/ReLU/shortcut epilogue fused in-kernel and
                  the halo DMA double-buffered whenever it fits VMEM
  "bsr"        -- block-sparse (BCSR) direct conv on the MXU: blocked
                  weight tiles contracted against on-chip-gathered im2col
                  patch tiles — dense-unit throughput for moderately-sparse
                  layers (Pallas kernel, interpret on CPU)
  "auto"       -- per-layer dispatch through a tuned plan from repro.tuning
                  (the paper's kernel customization, measurement-driven);
                  plan entries carry the full schedule: method, (tm, te,
                  tf) tiling, pad_to, fused epilogue, pipelined staging,
                  nnz-balanced channel packing, and the BCSR block shape

Execution goes through the compile-once graph engine (``repro.engine``):
the nested spec is lowered exactly once into a flat typed op program —
``init_cnn``, ``cnn_forward`` and ``conv_layer_shapes`` all delegate to
that single lowering pass instead of each re-walking the spec — and
``cnn_forward`` runs the program through a ``CnnEngine`` with a cached
``jax.jit`` per (method, input geometry).

Per-layer sparsities default to the Deep-Compression-era profile the paper's
SkimCaffe models carry (first conv kept dense — pruning conv1 hurts accuracy,
and the paper's models likewise keep some layers dense).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.engine import CnnEngine, METHODS, init_conv_params, lower
# Layer-spec vocabulary (historical home: this module; canonical home:
# repro.engine.spec — re-exported so existing callers keep working).
from repro.engine.spec import FC, Concat, Conv, Pool, Relu, Residual  # noqa: F401

CONV_METHODS = METHODS


# --------------------------------------------------------------------------
# network tables
# --------------------------------------------------------------------------

def alexnet() -> List[Any]:
    # Paper Table 3: 5 CONV layers, 4 sparse (conv1 dense).  Caffe AlexNet.
    return [
        Conv("conv1", 96, 11, 4, 0, sparsity=0.0), Relu(), Pool("max", 3, 2),
        Conv("conv2", 256, 5, 1, 2, sparsity=0.62), Relu(), Pool("max", 3, 2),
        Conv("conv3", 384, 3, 1, 1, sparsity=0.65), Relu(),
        Conv("conv4", 384, 3, 1, 1, sparsity=0.63), Relu(),
        Conv("conv5", 256, 3, 1, 1, sparsity=0.63), Relu(), Pool("max", 3, 2),
        FC("fc6", 4096, 0.91), Relu(), FC("fc7", 4096, 0.91),
        Relu(), FC("fc8", 1000, 0.75),
    ]


def _inception(name: str, c1: int, c3r: int, c3: int, c5r: int, c5: int,
               pp: int, sp: float) -> Concat:
    return Concat(branches=(
        (Conv(f"{name}/1x1", c1, 1, sparsity=sp), Relu()),
        (Conv(f"{name}/3x3_reduce", c3r, 1, sparsity=sp), Relu(),
         Conv(f"{name}/3x3", c3, 3, 1, 1, sparsity=sp), Relu()),
        (Conv(f"{name}/5x5_reduce", c5r, 1, sparsity=sp), Relu(),
         Conv(f"{name}/5x5", c5, 5, 1, 2, sparsity=sp), Relu()),
        (Pool("max", 3, 1, 1),
         Conv(f"{name}/pool_proj", pp, 1, sparsity=sp), Relu()),
    ))


def googlenet() -> List[Any]:
    # GoogLeNet v1 (57 CONV); the paper prunes 19 of them — we mark the 3x3/5x5
    # convs of the later inception modules sparse, reduces + early layers dense.
    s = 0.7
    return [
        Conv("conv1", 64, 7, 2, 3, sparsity=0.0), Relu(), Pool("max", 3, 2, 1),
        Conv("conv2_reduce", 64, 1, sparsity=0.0), Relu(),
        Conv("conv2", 192, 3, 1, 1, sparsity=0.62), Relu(), Pool("max", 3, 2, 1),
        _inception("3a", 64, 96, 128, 16, 32, 32, 0.0),
        _inception("3b", 128, 128, 192, 32, 96, 64, s),
        Pool("max", 3, 2, 1),
        _inception("4a", 192, 96, 208, 16, 48, 64, s),
        _inception("4b", 160, 112, 224, 24, 64, 64, s),
        _inception("4c", 128, 128, 256, 24, 64, 64, s),
        _inception("4d", 112, 144, 288, 32, 64, 64, s),
        _inception("4e", 256, 160, 320, 32, 128, 128, s),
        Pool("max", 3, 2, 1),
        _inception("5a", 256, 160, 320, 32, 128, 128, s),
        _inception("5b", 384, 192, 384, 48, 128, 128, s),
        Pool("gap"),
        FC("fc", 1000, 0.8),
    ]


def _bottleneck(name: str, mid: int, out: int, stride: int, sp: float,
                project: bool) -> Residual:
    body = (
        Conv(f"{name}/1x1a", mid, 1, stride, 0, sparsity=sp), Relu(),
        Conv(f"{name}/3x3", mid, 3, 1, 1, sparsity=sp), Relu(),
        Conv(f"{name}/1x1b", out, 1, sparsity=sp),
    )
    proj = Conv(f"{name}/proj", out, 1, stride, 0, sparsity=0.0) if project else None
    return Residual(body=body, proj=proj)


def resnet50() -> List[Any]:
    # 53 CONV layers; the paper's model has 16 sparse CONV layers — we prune
    # the 3x3 convs of stages 2-4 (16 of them), matching that count.
    layers: List[Any] = [
        Conv("conv1", 64, 7, 2, 3, sparsity=0.0), Relu(), Pool("max", 3, 2, 1)]
    stages = [("res2", 64, 256, 3, 0.0), ("res3", 128, 512, 4, 0.7),
              ("res4", 256, 1024, 6, 0.7), ("res5", 512, 2048, 3, 0.7)]
    for sname, mid, out, blocks, sp in stages:
        for b in range(blocks):
            stride = 2 if (b == 0 and sname != "res2") else 1
            layers.append(_bottleneck(f"{sname}{chr(97 + b)}", mid, out, stride,
                                      sp, project=(b == 0)))
            layers.append(Relu())
    layers += [Pool("gap"), FC("fc", 1000, 0.8)]
    return layers


NETWORKS = {"alexnet": alexnet, "googlenet": googlenet, "resnet50": resnet50}


# --------------------------------------------------------------------------
# engine delegation: one lowering pass feeds init, forward, and shape tables
# --------------------------------------------------------------------------

def _lowered(net: Sequence[Any], in_c: int, h: int, w: int):
    """Lower a spec once per (net, input geometry); memoized."""
    key = (tuple(net), in_c, h, w)
    prog = _PROGRAMS.get(key)
    if prog is None:
        prog = lower(net, (in_c, h, w))
        if len(_PROGRAMS) > 64:
            _PROGRAMS.clear()
        _PROGRAMS[key] = prog
    return prog


_PROGRAMS: Dict[Any, Any] = {}


def _params_fingerprint(params: Dict[str, Any]) -> Tuple[Any, ...]:
    """Identity snapshot of every parameter leaf.

    jax arrays are immutable, so any update — replacing a weight, or
    ``apply_plan_to_params`` adding ``ell_auto`` formats — rebinds a dict
    entry to a *new* object.  Fingerprinting leaf identities lets the
    engine memo detect such updates and rebind instead of replaying a jit
    that baked the old arrays in as constants (the legacy eager executor
    re-read params every call; compiled replay must not silently diverge
    from that).
    """
    out = []
    for name, entry in params.items():
        if isinstance(entry, dict):
            out.append((name, tuple((k, id(v)) for k, v in entry.items())))
        else:
            out.append((name, id(entry)))
    return tuple(out)


def engine_for(net: Sequence[Any], params: Dict[str, Any],
               in_shape: Tuple[int, int, int],
               plan: Optional[Dict[str, Any]] = None) -> CnnEngine:
    """A bound :class:`~repro.engine.CnnEngine` for (net, params, geometry).

    Engines are memoized on the lowered program plus the *identity* of
    ``params``/``plan`` — and a fingerprint of the parameter leaves, so a
    params update after a forward binds a fresh engine — letting repeated
    ``cnn_forward`` calls reuse each engine's per-(method, shape) compiled
    executables.
    """
    c, h, w = (int(d) for d in in_shape)
    program = _lowered(net, c, h, w)
    key = (id(program), id(params), id(plan))
    fp = _params_fingerprint(params)
    hit = _ENGINES.get(key)
    # id() can be recycled after gc: verify the cached engine still binds
    # the same live objects (and the same parameter leaves) before reusing.
    if (hit is not None and hit[1] == fp):
        eng = hit[0]
        if eng.program is program and eng.params is params and eng.plan is plan:
            return eng
    if len(_ENGINES) > 64:
        _ENGINES.clear()
    eng = CnnEngine(program, params, plan)
    _ENGINES[key] = (eng, fp)
    return eng


_ENGINES: Dict[Any, Tuple[CnnEngine, Tuple[Any, ...]]] = {}


def init_cnn(net: Sequence[Any], in_c: int, rng: np.random.Generator,
             image: int = 224) -> Dict[str, Any]:
    """Random pruned weights for every layer (magnitude pruning at each
    layer's configured sparsity), plus precomputed Escoin formats.

    Delegates to the engine's single lowering pass — the conv table drives
    RNG draws in the historical spec-walk order, so weights are
    bit-identical to the pre-engine walker's.
    """
    return init_conv_params(_lowered(net, in_c, image, image), rng)


def cnn_forward(net: Sequence[Any], params: Dict[str, Any], x: jax.Array,
                method: str = "dense",
                plan: Optional[Dict[str, Any]] = None) -> jax.Array:
    """Run the whole network; FC layers run dense (paper measures CONV).

    ``method="auto"`` dispatches each conv through its tuned plan entry
    (``repro.tuning``).  With no plan supplied, a roofline-mode plan is
    computed on the fly from the input geometry (no measurement needed).
    Execution is the engine's cached-jit program replay; FC weights come
    from the engine bind (never created inside a trace).
    """
    engine = engine_for(net, params, x.shape[1:], plan)
    return engine(x, method)


def conv_layer_shapes(net: Sequence[Any], in_c: int, image: int,
                      ) -> List[Tuple[Conv, Tuple[int, int, int]]]:
    """Static (layer, (C, H, W)) input-shape table for benchmarks."""
    return list(_lowered(net, in_c, image, image).conv_table)
