"""The paper's CNN benchmark models: AlexNet, GoogLeNet (v1), ResNet-50.

Each network is a table of layer specs; convolutions execute through a
selectable method (paper Table/Figs 8-11):

  "dense"      -- XLA dense conv on zero-filled weights   (CUBLAS analogue)
  "lowered"    -- im2col + ELL(CSR) SpMM                  (CUSPARSE analogue)
  "csr-direct" -- Escoin direct sparse conv, pure-JAX scan
  "pallas"     -- Escoin direct sparse conv, Pallas kernel (interpret on CPU)
  "auto"       -- per-layer dispatch through a tuned plan from repro.tuning
                  (the paper's kernel customization, measurement-driven)

Per-layer sparsities default to the Deep-Compression-era profile the paper's
SkimCaffe models carry (first conv kept dense — pruning conv1 hurts accuracy,
and the paper's models likewise keep some layers dense).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.direct_conv import dense_conv, direct_sparse_conv
from repro.core.lowering import lowered_dense_conv, lowered_sparse_conv
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import ell_from_dense, ell_from_dense_conv
from repro.kernels.sparse_conv.ops import sparse_conv as pallas_sparse_conv

CONV_METHODS = ("dense", "lowered", "csr-direct", "pallas", "auto")


@dataclasses.dataclass(frozen=True)
class Conv:
    name: str
    out_c: int
    k: int
    stride: int = 1
    pad: int = 0
    sparsity: float = 0.85   # 0.0 => layer kept dense (runs dense always)


@dataclasses.dataclass(frozen=True)
class Pool:
    kind: str                # max | avg | gap
    k: int = 3
    stride: int = 2
    pad: int = 0


@dataclasses.dataclass(frozen=True)
class FC:
    name: str
    out_f: int
    sparsity: float = 0.9


@dataclasses.dataclass(frozen=True)
class Concat:
    """Inception module: parallel branches concatenated on channels."""
    branches: Tuple[Tuple[Any, ...], ...]


@dataclasses.dataclass(frozen=True)
class Residual:
    """ResNet bottleneck: body branch + (optional projection) shortcut."""
    body: Tuple[Any, ...]
    proj: Optional[Conv] = None


@dataclasses.dataclass(frozen=True)
class Relu:
    pass


# --------------------------------------------------------------------------
# network tables
# --------------------------------------------------------------------------

def alexnet() -> List[Any]:
    # Paper Table 3: 5 CONV layers, 4 sparse (conv1 dense).  Caffe AlexNet.
    return [
        Conv("conv1", 96, 11, 4, 0, sparsity=0.0), Relu(), Pool("max", 3, 2),
        Conv("conv2", 256, 5, 1, 2, sparsity=0.62), Relu(), Pool("max", 3, 2),
        Conv("conv3", 384, 3, 1, 1, sparsity=0.65), Relu(),
        Conv("conv4", 384, 3, 1, 1, sparsity=0.63), Relu(),
        Conv("conv5", 256, 3, 1, 1, sparsity=0.63), Relu(), Pool("max", 3, 2),
        FC("fc6", 4096, 0.91), Relu(), FC("fc7", 4096, 0.91),
        Relu(), FC("fc8", 1000, 0.75),
    ]


def _inception(name: str, c1: int, c3r: int, c3: int, c5r: int, c5: int,
               pp: int, sp: float) -> Concat:
    return Concat(branches=(
        (Conv(f"{name}/1x1", c1, 1, sparsity=sp), Relu()),
        (Conv(f"{name}/3x3_reduce", c3r, 1, sparsity=sp), Relu(),
         Conv(f"{name}/3x3", c3, 3, 1, 1, sparsity=sp), Relu()),
        (Conv(f"{name}/5x5_reduce", c5r, 1, sparsity=sp), Relu(),
         Conv(f"{name}/5x5", c5, 5, 1, 2, sparsity=sp), Relu()),
        (Pool("max", 3, 1, 1),
         Conv(f"{name}/pool_proj", pp, 1, sparsity=sp), Relu()),
    ))


def googlenet() -> List[Any]:
    # GoogLeNet v1 (57 CONV); the paper prunes 19 of them — we mark the 3x3/5x5
    # convs of the later inception modules sparse, reduces + early layers dense.
    s = 0.7
    return [
        Conv("conv1", 64, 7, 2, 3, sparsity=0.0), Relu(), Pool("max", 3, 2, 1),
        Conv("conv2_reduce", 64, 1, sparsity=0.0), Relu(),
        Conv("conv2", 192, 3, 1, 1, sparsity=0.62), Relu(), Pool("max", 3, 2, 1),
        _inception("3a", 64, 96, 128, 16, 32, 32, 0.0),
        _inception("3b", 128, 128, 192, 32, 96, 64, s),
        Pool("max", 3, 2, 1),
        _inception("4a", 192, 96, 208, 16, 48, 64, s),
        _inception("4b", 160, 112, 224, 24, 64, 64, s),
        _inception("4c", 128, 128, 256, 24, 64, 64, s),
        _inception("4d", 112, 144, 288, 32, 64, 64, s),
        _inception("4e", 256, 160, 320, 32, 128, 128, s),
        Pool("max", 3, 2, 1),
        _inception("5a", 256, 160, 320, 32, 128, 128, s),
        _inception("5b", 384, 192, 384, 48, 128, 128, s),
        Pool("gap"),
        FC("fc", 1000, 0.8),
    ]


def _bottleneck(name: str, mid: int, out: int, stride: int, sp: float,
                project: bool) -> Residual:
    body = (
        Conv(f"{name}/1x1a", mid, 1, stride, 0, sparsity=sp), Relu(),
        Conv(f"{name}/3x3", mid, 3, 1, 1, sparsity=sp), Relu(),
        Conv(f"{name}/1x1b", out, 1, sparsity=sp),
    )
    proj = Conv(f"{name}/proj", out, 1, stride, 0, sparsity=0.0) if project else None
    return Residual(body=body, proj=proj)


def resnet50() -> List[Any]:
    # 53 CONV layers; the paper's model has 16 sparse CONV layers — we prune
    # the 3x3 convs of stages 2-4 (16 of them), matching that count.
    layers: List[Any] = [
        Conv("conv1", 64, 7, 2, 3, sparsity=0.0), Relu(), Pool("max", 3, 2, 1)]
    stages = [("res2", 64, 256, 3, 0.0), ("res3", 128, 512, 4, 0.7),
              ("res4", 256, 1024, 6, 0.7), ("res5", 512, 2048, 3, 0.7)]
    for sname, mid, out, blocks, sp in stages:
        for b in range(blocks):
            stride = 2 if (b == 0 and sname != "res2") else 1
            layers.append(_bottleneck(f"{sname}{chr(97 + b)}", mid, out, stride,
                                      sp, project=(b == 0)))
            layers.append(Relu())
    layers += [Pool("gap"), FC("fc", 1000, 0.8)]
    return layers


NETWORKS = {"alexnet": alexnet, "googlenet": googlenet, "resnet50": resnet50}


# --------------------------------------------------------------------------
# init + forward
# --------------------------------------------------------------------------

def init_cnn(net: Sequence[Any], in_c: int, rng: np.random.Generator,
             image: int = 224) -> Dict[str, Any]:
    """Random pruned weights for every layer (magnitude pruning at each
    layer's configured sparsity), plus precomputed Escoin formats."""
    params: Dict[str, Any] = {}

    def walk(layers, c):
        for l in layers:
            if isinstance(l, Conv):
                w = (rng.standard_normal((l.out_c, c, l.k, l.k))
                     .astype(np.float32) * (2.0 / (c * l.k * l.k)) ** 0.5)
                if l.sparsity > 0:
                    w = np.asarray(magnitude_prune(jnp.asarray(w), l.sparsity))
                entry = {"w": jnp.asarray(w),
                         "b": jnp.zeros((l.out_c,), jnp.float32)}
                if l.sparsity > 0:
                    entry["ell"] = ell_from_dense_conv(w)
                    entry["ell2d"] = ell_from_dense(w.reshape(l.out_c, -1))
                params[l.name] = entry
                c = l.out_c
            elif isinstance(l, Concat):
                c = sum(walk(br, c) for br in l.branches)
            elif isinstance(l, Residual):
                cb = walk(l.body, c)
                if l.proj is not None:
                    walk((l.proj,), c)
                c = cb
            elif isinstance(l, FC):
                pass  # handled at forward time with lazily-known in dim
            # Pool / Relu: no params
        return c

    walk(net, in_c)
    params["_fc_rng"] = rng.integers(0, 2**31)
    return params


def _conv_apply(l: Conv, entry: Dict[str, Any], x: jax.Array, method: str,
                plan: Optional[Dict[str, Any]] = None) -> jax.Array:
    tm = te = tf = None
    if method == "auto":
        # Per-layer kernel customization: the tuned plan names the method
        # (and tm/te/tf/pad_to) for this layer; missing entries fall back
        # dense.  Strided layers are pallas-eligible — the kernel applies
        # the stride in-kernel.
        pe = (plan or {}).get(l.name)
        method = pe.method if pe is not None else "dense"
        if pe is not None:
            tm, te, tf = pe.tm, pe.te, pe.tf
        ell = entry.get("ell_auto", entry.get("ell"))
        ell2d = entry.get("ell2d_auto", entry.get("ell2d"))
    else:
        ell, ell2d = entry.get("ell"), entry.get("ell2d")
    if l.sparsity == 0 or method == "dense":
        y = dense_conv(x, entry["w"], stride=l.stride, padding=l.pad)
    elif method == "lowered":
        y = lowered_sparse_conv(x, ell2d, l.k, l.k,
                                stride=l.stride, padding=l.pad)
    elif method == "csr-direct":
        y = direct_sparse_conv(x, ell, stride=l.stride, padding=l.pad)
    elif method == "pallas":
        y = pallas_sparse_conv(x, ell, stride=l.stride, padding=l.pad,
                               tm=tm, te=te, tf=tf, interpret=True)
    else:
        raise ValueError(method)
    return y + entry["b"][None, :, None, None]


def _pool(l: Pool, x: jax.Array) -> jax.Array:
    if l.kind == "gap":
        return x.mean(axis=(2, 3), keepdims=True)
    init = -jnp.inf if l.kind == "max" else 0.0
    op = jax.lax.max if l.kind == "max" else jax.lax.add
    y = jax.lax.reduce_window(
        x, init, op, (1, 1, l.k, l.k), (1, 1, l.stride, l.stride),
        ((0, 0), (0, 0), (l.pad, l.pad), (l.pad, l.pad)))
    if l.kind == "avg":
        y = y / (l.k * l.k)
    return y


def cnn_forward(net: Sequence[Any], params: Dict[str, Any], x: jax.Array,
                method: str = "dense",
                plan: Optional[Dict[str, Any]] = None) -> jax.Array:
    """Run the whole network; FC layers run dense (paper measures CONV).

    ``method="auto"`` dispatches each conv through its tuned plan entry
    (``repro.tuning``).  With no plan supplied, a roofline-mode plan is
    computed on the fly from the input geometry (no measurement needed).
    """
    if method == "auto" and plan is None:
        from repro.tuning.planner import plan_network  # lazy: avoids cycle
        plan = plan_network(net, int(x.shape[1]), int(x.shape[2]),
                            batch=int(x.shape[0]), mode="roofline")
    fc_rng = np.random.default_rng(int(params["_fc_rng"]))

    def walk(layers, x):
        for l in layers:
            if isinstance(l, Conv):
                x = _conv_apply(l, params[l.name], x, method, plan)
            elif isinstance(l, Relu):
                x = jax.nn.relu(x)
            elif isinstance(l, Pool):
                x = _pool(l, x)
            elif isinstance(l, Concat):
                x = jnp.concatenate([walk(br, x) for br in l.branches], axis=1)
            elif isinstance(l, Residual):
                y = walk(l.body, x)
                sc = (_conv_apply(l.proj, params[l.proj.name], x, method, plan)
                      if l.proj is not None else x)
                x = y + sc
            elif isinstance(l, FC):
                flat = x.reshape(x.shape[0], -1)
                key = f"{l.name}:{flat.shape[1]}"
                if key not in params:
                    # cache as numpy: a jnp constant created inside a jit
                    # trace would be a tracer and leak across traces
                    params[key] = (
                        fc_rng.standard_normal((flat.shape[1], l.out_f))
                        .astype(np.float32) * (1.0 / flat.shape[1]) ** 0.5)
                x = flat @ params[key]
        return x

    return walk(net, x)


def conv_layer_shapes(net: Sequence[Any], in_c: int, image: int,
                      ) -> List[Tuple[Conv, Tuple[int, int, int]]]:
    """Static (layer, (C, H, W)) input-shape table for benchmarks."""
    out: List[Tuple[Conv, Tuple[int, int, int]]] = []

    def walk(layers, c, hw):
        for l in layers:
            if isinstance(l, Conv):
                out.append((l, (c, hw, hw)))
                hw = (hw + 2 * l.pad - l.k) // l.stride + 1
                c = l.out_c
            elif isinstance(l, Pool):
                if l.kind == "gap":
                    hw = 1
                else:
                    hw = (hw + 2 * l.pad - l.k) // l.stride + 1
            elif isinstance(l, Concat):
                subs = [walk(br, c, hw) for br in l.branches]
                c = sum(s[0] for s in subs)
                hw = subs[0][1]
            elif isinstance(l, Residual):
                cb, hwb = walk(l.body, c, hw)
                if l.proj is not None:
                    walk((l.proj,), c, hw)
                c, hw = cb, hwb
        return c, hw

    walk(net, in_c, image)
    return out
