"""Model building blocks shared by all assigned architectures.

Conventions:
  * params are nested dicts of arrays; linear weights are (in_features,
    out_features) so application is ``x @ w``.
  * every ``init_*`` has a mirror ``specs_*`` producing PartitionSpecs with
    *logical* axis names ("fsdp", "tp") resolved by distributed/sharding.py.
  * weights can be swapped for ``BcsrMatrix`` (Escoin block-sparse) leaves at
    serve time; ``apply_linear`` dispatches on leaf type, so every projection
    in every architecture is a sparsity site (DESIGN.md §4).
  * attention is chunked with an online softmax so no (T, T) tensor is ever
    materialised (required for the 32k shapes).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.sparse_format import BcsrMatrix, EllMatrix
from repro.core.sparse_linear import bcsr_matmul, ell_matmul
from repro.models import flags as F
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _norm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype=dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def apply_linear(w, x: jax.Array, bias: Optional[jax.Array] = None) -> jax.Array:
    """Linear application dispatching on the weight's storage format.

    Dense (in, out) array -> x @ w.  BcsrMatrix / EllMatrix of logical shape
    (out, in) -> Escoin sparse path.
    """
    if isinstance(w, BcsrMatrix):
        y = bcsr_matmul(x, w)
    elif isinstance(w, EllMatrix):
        y = ell_matmul(x, w)
    else:
        y = jnp.einsum("...i,io->...o", x, w, preferred_element_type=jnp.float32)
        y = y.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: (..., T, H, hd), positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # positions: (B, T) -> angles (B, T, 1, half)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _maybe(n: int, size: int, axis: str) -> Optional[str]:
    """Shard dim of length n over ``axis`` only if divisible (DESIGN §5)."""
    return axis if size > 0 and n % size == 0 else None


# ---------------------------------------------------------------------------
# full-sequence attention: flash Pallas kernel (flags.ATTN_IMPL="flash") or
# the jnp chunked online-softmax fallback — no (T, T) materialisation either way
# ---------------------------------------------------------------------------

def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                   scale: Optional[float] = None) -> jax.Array:
    """Dispatcher: q (B, T, H, hd), k/v (B, S, KV, hdv) -> (B, T, H, hdv).

    flash path: Pallas kernel, sharded by hand over the tp axis via partial
    shard_map (custom calls are not SPMD-partitionable).  GQA head grouping
    is preserved across shards in two regimes:
      A: whole kv groups per shard  ((H/tp) % g == 0) — kv heads sharded;
      B: sub-group shards (g % (H/tp) == 0) — kv replicated, each shard
         dynamic-slices its single kv head.
    Shapes outside both regimes (e.g. qwen-4b's 20 heads on tp=16) fall back
    to the chunked jnp path.  flash also requires hd == hdv (not MLA prefill's
    192/128 split).
    """
    if F.ATTN_IMPL != "flash" or q.shape[-1] != v.shape[-1]:
        return chunked_attention(q, k, v, causal=causal, scale=scale)
    import functools as _ft

    from repro.distributed import sharding as shd
    from repro.kernels.flash_attention.ops import flash_attention_bthd

    interp = jax.default_backend() == "cpu"
    call = _ft.partial(flash_attention_bthd, causal=causal, scale=scale,
                       interpret=interp)
    mesh = shd.get_mesh()
    h, kvh = q.shape[2], k.shape[2]
    g = h // kvh
    if mesh is None:
        return call(q, k, v)
    rules = shd.get_rules() or {}
    ax = rules.get("tp")
    if ax not in mesh.axis_names:
        return call(q, k, v)
    tp = mesh.shape[ax]
    if h % tp:
        return chunked_attention(q, k, v, causal=causal, scale=scale)
    hq = h // tp
    if hq % g == 0:
        kv_spec = P(None, None, ax, None)
        mode = "A"
    elif g % hq == 0:
        kv_spec = P(None, None, None, None)
        mode = "B"
    else:
        return chunked_attention(q, k, v, causal=causal, scale=scale)

    def local(qL, kL, vL):
        if mode == "B":
            idx = (jax.lax.axis_index(ax) * hq) // g
            kL = lax.dynamic_slice_in_dim(kL, idx, 1, axis=2)
            vL = lax.dynamic_slice_in_dim(vL, idx, 1, axis=2)
        return call(qL, kL, vL)

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(P(None, None, ax, None), kv_spec, kv_spec),
                         out_specs=P(None, None, ax, None),
                         axis_names={ax}, check_vma=False)(q, k, v)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk_q: Optional[int] = None,
                      chunk_k: Optional[int] = None,
                      scale: Optional[float] = None) -> jax.Array:
    """q: (B, T, H, hd), k/v: (B, S, KV, hd[v]) -> (B, T, H, hdv).

    GQA: H is a multiple of KV; kv heads are repeated logically via reshape.
    Double lax.scan (q chunks outer, kv chunks inner) keeps HLO size O(1) in T
    and the live buffer at (B, H, cq, ck).  Under flags.UNROLL (roofline probe
    compiles) both scans fully unroll so HloCostAnalysis sees every chunk.
    """
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    chunk_q = chunk_q or F.ATTN_CHUNK
    chunk_k = chunk_k or F.ATTN_CHUNK
    cq, ck = min(chunk_q, t), min(chunk_k, s)
    nq, nk = t // cq, s // ck
    assert t % cq == 0 and s % ck == 0, (t, s, cq, ck)

    qc = q.reshape(b, nq, cq, kv, g, hd).astype(jnp.float32) * scale
    kc = k.reshape(b, nk, ck, kv, hd).astype(jnp.float32)
    vc = v.reshape(b, nk, ck, kv, hdv).astype(jnp.float32)

    def q_step(_, qi):
        qblk, qidx = qi  # (B, cq, KV, G, hd), scalar chunk index

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk)  # (B,KV,G,cq,ck)
            if causal:
                qpos = qidx * cq + jnp.arange(cq)
                kpos = kidx * ck + jnp.arange(ck)
                mask = qpos[:, None] >= kpos[None, :]
                logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vblk)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, hdv), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)), unroll=F.UNROLL)
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,G,cq,hdv)
        return None, out.transpose(0, 3, 1, 2, 4)             # (B,cq,KV,G,hdv)

    _, outs = lax.scan(q_step, None,
                       (qc.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nq)),
                       unroll=F.UNROLL)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, h, hdv)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, scale: Optional[float] = None) -> jax.Array:
    """Single-position attention over a KV cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd); cur_len: () current length.
    """
    b, _, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    mask = jnp.arange(s) < cur_len
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def specs_attention(cfg: ModelConfig, tp: int) -> Params:
    hd = cfg.head_dim
    qo = _maybe(cfg.n_heads * hd, tp, "tp")
    kvo = _maybe(cfg.n_kv_heads * hd, tp, "tp")
    p = {
        "wq": P("fsdp", qo), "wk": P("fsdp", kvo), "wv": P("fsdp", kvo),
        "wo": P(qo, "fsdp"),
    }
    if cfg.qkv_bias:
        p.update({"bq": P(qo), "bk": P(kvo), "bv": P(kvo)})
    return p


def attention_fwd(p: Params, x: jax.Array, positions: jax.Array,
                  cfg: ModelConfig, *, cache: Optional[Params] = None,
                  cur_len: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Optional[Params]]:
    b, t, _ = x.shape
    hd = cfg.head_dim
    q = apply_linear(p["wq"], x, p.get("bq")).reshape(b, t, cfg.n_heads, hd)
    k = apply_linear(p["wk"], x, p.get("bk")).reshape(b, t, cfg.n_kv_heads, hd)
    v = apply_linear(p["wv"], x, p.get("bv")).reshape(b, t, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = full_attention(q, k, v, causal=cfg.causal)
        new_cache = None
    else:
        kc = lax.dynamic_update_slice(cache["k"], k, (0, cur_len, 0, 0))
        vc = lax.dynamic_update_slice(cache["v"], v, (0, cur_len, 0, 0))
        out = decode_attention(q, kc, vc, cur_len + 1)
        new_cache = {"k": kc, "v": vc}
    out = out.reshape(b, t, cfg.n_heads * hd)
    return apply_linear(p["wo"], out), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def specs_attention_cache(cfg: ModelConfig, tp: int) -> Params:
    # Prefer sharding KV heads over the model axis; when head count does not
    # divide (GQA kv=8 on tp=16), shard the sequence axis instead so the
    # 32k/500k caches still split 256 ways (DESIGN.md §5).
    if tp and cfg.n_kv_heads % tp == 0:
        spec = P("dp", None, "tp", None)
    else:
        spec = P("dp", "sp", None, None)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p: Params = {}
    if cfg.q_lora_rank:
        p["q_a"] = dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype)
        p["q_norm"] = _norm_init(cfg.q_lora_rank, dtype)
        p["q_b"] = dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_hd, dtype)
    else:
        p["q_b"] = dense_init(ks[1], cfg.d_model, cfg.n_heads * qk_hd, dtype)
    p["kv_a"] = dense_init(ks[2], cfg.d_model,
                           cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype)
    p["kv_norm"] = _norm_init(cfg.kv_lora_rank, dtype)
    p["k_b"] = dense_init(ks[3], cfg.kv_lora_rank,
                          cfg.n_heads * cfg.qk_nope_head_dim, dtype)
    p["v_b"] = dense_init(ks[4], cfg.kv_lora_rank,
                          cfg.n_heads * cfg.v_head_dim, dtype)
    p["wo"] = dense_init(ks[5], cfg.n_heads * cfg.v_head_dim, cfg.d_model, dtype)
    return p


def specs_mla(cfg: ModelConfig, tp: int) -> Params:
    qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p: Params = {}
    if cfg.q_lora_rank:
        p["q_a"] = P("fsdp", None)
        p["q_norm"] = P(None)
        p["q_b"] = P(None, _maybe(cfg.n_heads * qk_hd, tp, "tp"))
    else:
        p["q_b"] = P("fsdp", _maybe(cfg.n_heads * qk_hd, tp, "tp"))
    p["kv_a"] = P("fsdp", None)
    p["kv_norm"] = P(None)
    p["k_b"] = P(None, _maybe(cfg.n_heads * cfg.qk_nope_head_dim, tp, "tp"))
    p["v_b"] = P(None, _maybe(cfg.n_heads * cfg.v_head_dim, tp, "tp"))
    p["wo"] = P(_maybe(cfg.n_heads * cfg.v_head_dim, tp, "tp"), "fsdp")
    return p


def mla_fwd(p: Params, x: jax.Array, positions: jax.Array, cfg: ModelConfig, *,
            cache: Optional[Params] = None, cur_len: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Optional[Params]]:
    b, t, _ = x.shape
    h = cfg.n_heads
    nope, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (nope + rd) ** -0.5

    if cfg.q_lora_rank:
        q_c = rms_norm(apply_linear(p["q_a"], x), p["q_norm"], cfg.norm_eps)
    else:
        q_c = x
    q = apply_linear(p["q_b"], q_c).reshape(b, t, h, nope + rd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = apply_linear(p["kv_a"], x)
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(kv[..., cfg.kv_lora_rank:][:, :, None, :], positions,
                  cfg.rope_theta)[:, :, 0, :]                    # (B, T, rd)

    if cache is None:
        # Prefill: expand per-head keys/values, chunked attention.
        k_nope = apply_linear(p["k_b"], c_kv).reshape(b, t, h, nope)
        v = apply_linear(p["v_b"], c_kv).reshape(b, t, h, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, rd))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = full_attention(q_full, k, v, causal=cfg.causal, scale=scale)
        new_cache = None
    else:
        # Decode: *absorbed* MLA — attend in the compressed latent space so
        # the cache stays (B, S, kv_lora_rank + rope) and no per-head K/V is
        # ever expanded (the memory win that makes 671B decode viable).
        ckv_c = lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, cur_len, 0))
        krope_c = lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, cur_len, 0))
        w_kb = p["k_b"].reshape(cfg.kv_lora_rank, h, nope)
        q_abs = jnp.einsum("bthd,lhd->bthl", q_nope.astype(jnp.float32),
                           w_kb.astype(jnp.float32))
        # logits over latent cache + rope part
        logits = (jnp.einsum("bthl,bsl->bhts", q_abs, ckv_c.astype(jnp.float32))
                  + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                               krope_c.astype(jnp.float32))) * scale
        s = ckv_c.shape[1]
        mask = jnp.arange(s) < (cur_len + 1)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        pattn = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhts,bsl->bthl", pattn, ckv_c.astype(jnp.float32))
        w_vb = p["v_b"].reshape(cfg.kv_lora_rank, h, vd)
        out = jnp.einsum("bthl,lhd->bthd", o_lat,
                         w_vb.astype(jnp.float32)).astype(x.dtype)
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c}
    out = out.reshape(b, t, h * vd)
    return apply_linear(p["wo"], out), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def specs_mla_cache(cfg: ModelConfig, tp: int) -> Params:
    # Latent cache has no head axis; shard the sequence over the model axis.
    return {"c_kv": P("dp", "sp", None), "k_rope": P("dp", "sp", None)}


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if act == "swiglu":
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def specs_mlp(d_ff: int, act: str, tp: int) -> Params:
    f = _maybe(d_ff, tp, "tp")
    p = {"up": P("fsdp", f), "down": P(f, "fsdp")}
    if act == "swiglu":
        p["gate"] = P("fsdp", f)
    return p


def mlp_fwd(p: Params, x: jax.Array, act: str) -> jax.Array:
    up = apply_linear(p["up"], x)
    if act == "swiglu":
        h = jax.nn.silu(apply_linear(p["gate"], x)) * up
    else:
        h = jax.nn.gelu(up)
    return apply_linear(p["down"], h)


# ---------------------------------------------------------------------------
# MoE with gather-based (sort-free-FLOPs) dispatch
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    e = cfg.n_experts
    dff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], cfg.d_model, e, jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, cfg.d_model, dff), jnp.float32)
                   * (1.0 / cfg.d_model) ** 0.5).astype(dtype),
        "w_up": (jax.random.truncated_normal(ks[2], -2, 2, (e, cfg.d_model, dff), jnp.float32)
                 * (1.0 / cfg.d_model) ** 0.5).astype(dtype),
        "w_down": (jax.random.truncated_normal(ks[3], -2, 2, (e, dff, cfg.d_model), jnp.float32)
                   * (1.0 / dff) ** 0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg.d_model,
                               cfg.n_shared_experts * dff, "swiglu", dtype)
    return p


def specs_moe(cfg: ModelConfig, tp: int) -> Params:
    e = _maybe(cfg.n_experts, tp, "tp")
    dff = cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": P("fsdp", None),
        "w_gate": P(e, "fsdp", None),
        "w_up": P(e, "fsdp", None),
        "w_down": P(e, None, "fsdp"),
    }
    if cfg.n_shared_experts:
        p["shared"] = specs_mlp(cfg.n_shared_experts * dff, "swiglu", tp)
    return p


def _moe_group(p: Params, xg: jax.Array, cfg: ModelConfig,
               capacity: int) -> jax.Array:
    """Route one group of tokens. xg: (G, D) -> (G, D).

    Gather-based dispatch (DESIGN.md §4): index arrays are built with
    sort/searchsorted (integer work, no matmul FLOPs), then tokens move via
    two gathers — the SPMD analogue of the expert-parallel all-to-all, at
    activation-volume cost instead of the O(T*E*C*D) one-hot einsum.
    """
    g, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("gd,de->ge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, k)                     # (G, K)
    topw = (topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)).astype(jnp.float32)

    flat_e = topi.reshape(-1)                            # (G*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos = jnp.arange(g * k) - start[sorted_e]
    ok = pos < capacity
    slot = jnp.where(ok, sorted_e * capacity + pos, e * capacity)  # overflow -> trash
    tok = order // k
    # token feeding each (expert, slot); sentinel g -> zero row
    token_for_slot = jnp.full((e * capacity + 1,), g, jnp.int32).at[slot].set(
        tok.astype(jnp.int32), mode="drop")
    slot_for_tokk = jnp.full((g * k,), e * capacity, jnp.int32).at[order].set(
        jnp.where(ok, slot, e * capacity).astype(jnp.int32))

    def _c(arr, *names):
        """§Perf fix (EXPERIMENTS.md, deepseek hillclimb): pin the expert axis
        of every dispatch buffer to the tp axis so XLA routes tokens with an
        expert-parallel all-to-all instead of full all-gathers."""
        if not F.MOE_CONSTRAIN:
            return arr
        from repro.distributed.sharding import constrain
        return constrain(arr, *names)

    xpad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], 0)
    dispatched = xpad[token_for_slot[: e * capacity]].reshape(e, capacity, d)
    dispatched = _c(dispatched, "tp", None, None)
    hg = jnp.einsum("ecd,edf->ecf", dispatched, p["w_gate"],
                    preferred_element_type=jnp.float32)
    hu = jnp.einsum("ecd,edf->ecf", dispatched, p["w_up"],
                    preferred_element_type=jnp.float32)
    hy = (jax.nn.silu(hg) * hu).astype(xg.dtype)
    hy = _c(hy, "tp", None, None)
    y = jnp.einsum("ecf,efd->ecd", hy, p["w_down"],
                   preferred_element_type=jnp.float32).astype(xg.dtype)
    y = _c(y, "tp", None, None)
    ypad = jnp.concatenate([y.reshape(e * capacity, d),
                            jnp.zeros((1, d), y.dtype)], 0)
    per_k = ypad[slot_for_tokk].reshape(g, k, d)
    per_k = _c(per_k, ("dp", "sp"), None, None)
    out = jnp.einsum("gk,gkd->gd", topw, per_k.astype(jnp.float32)).astype(xg.dtype)
    return out


def moe_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
            group_size: Optional[int] = None,
            capacity_factor: Optional[float] = None) -> jax.Array:
    """x: (B, T, D).

    Default: one group over all tokens (no loop; dispatch/combine are single
    gathers, SPMD-sharded).  ``group_size`` bounds the transient working set
    on small-memory runs; the group loop fully unrolls under flags.UNROLL.
    """
    if F.MOE_IMPL == "ep":
        from repro.distributed.sharding import get_mesh, get_rules
        mesh, rules = get_mesh(), get_rules() or {}
        ax = rules.get("tp")
        if (mesh is not None and ax in mesh.axis_names
                and cfg.n_experts % mesh.shape[ax] == 0
                and mesh.shape[ax] > 1):
            from repro.models.moe_ep import moe_fwd_ep
            return moe_fwd_ep(p, x, cfg)
    if capacity_factor is None:
        capacity_factor = F.MOE_CAPACITY
    b, t, d = x.shape
    flat = x.reshape(b * t, d)
    if F.MOE_CONSTRAIN:
        from repro.distributed.sharding import constrain
        flat = constrain(flat, ("dp", "sp"), None)
    n = flat.shape[0]
    gsz = n if group_size is None else min(group_size, n)
    if n % gsz:
        gsz = n  # tiny/ragged inputs: single group
    cap = int(gsz * cfg.top_k / cfg.n_experts * capacity_factor)
    cap = max(8, ((cap + 7) // 8) * 8)
    if gsz == n:
        out = _moe_group(p, flat, cfg=cfg, capacity=cap)
    else:
        groups = flat.reshape(n // gsz, gsz, d)
        _, out = lax.scan(
            lambda _, g: (None, _moe_group(p, g, cfg=cfg, capacity=cap)),
            None, groups, unroll=F.UNROLL)
    out = out.reshape(b, t, d)
    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], x, "swiglu")
    return out


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state space duality, chunked matmul form)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    conv_dim = di + 2 * ns
    return {
        # order: [z (di), x (di), B (ns), C (ns), dt (nh)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ns + nh, dtype),
        "conv_w": (jax.random.truncated_normal(
            ks[1], -2, 2, (cfg.ssm_conv_width, conv_dim), jnp.float32) * 0.1
        ).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": _norm_init(di, dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def specs_mamba2(cfg: ModelConfig, tp: int) -> Params:
    nh = _maybe(cfg.n_ssm_heads, tp, "tp")
    di = _maybe(cfg.d_inner, tp, "tp")
    return {
        "in_proj": P("fsdp", None),
        "conv_w": P(None, None), "conv_b": P(None),
        "a_log": P(nh), "d_skip": P(nh), "dt_bias": P(nh),
        "norm": P(di),
        "out_proj": P(di, "fsdp"),
    }


def _ssd_scan(xh: jax.Array, dt: jax.Array, a_log: jax.Array, bmat: jax.Array,
              cmat: jax.Array, chunk: int,
              init_state: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: y_t = C_t . h_t,  h_t = exp(-exp(A)dt_t) h_{t-1} + dt_t B_t x_t.

    xh: (B, T, nh, hd); dt: (B, T, nh); bmat/cmat: (B, T, ns).
    Returns (y (B,T,nh,hd), final_state (B,nh,ns,hd)).
    Intra-chunk work is attention-like matmuls (MXU-friendly); inter-chunk a
    sequential scan over T/chunk steps carrying (B, nh, ns, hd).
    """
    b, t, nh, hd = xh.shape
    ns = bmat.shape[-1]
    q = min(chunk, t)
    assert t % q == 0
    nchunk = t // q
    a = -jnp.exp(a_log)                                   # (nh,)
    dta = dt * a[None, None, :]                           # (B, T, nh)
    xdt = xh * dt[..., None]                              # dt-weighted input

    def to_chunks(z):
        return z.reshape((b, nchunk, q) + z.shape[2:]).transpose(1, 0, *range(2, z.ndim + 1))

    xc = to_chunks(xdt)      # (nc, B, q, nh, hd)
    dtac = to_chunks(dta)    # (nc, B, q, nh)
    bc = to_chunks(bmat)     # (nc, B, q, ns)
    cc = to_chunks(cmat)     # (nc, B, q, ns)

    def step(h, inp):
        xq, dq, bq, cq = inp
        cs = jnp.cumsum(dq, axis=1)                       # (B, q, nh) cumulative log-decay
        total = cs[:, -1]                                 # (B, nh)
        # intra-chunk (causal "attention" with decay weights)
        li = cs[:, :, None, :] - cs[:, None, :, :]        # (B, q, q, nh) decay i<-j
        mask = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: entries above the diagonal are positive and would
        # overflow float32 for long chunks / fast-decaying heads.
        li = jnp.where(mask[None, :, :, None], li, -jnp.inf)
        w = jnp.exp(li)
        scores = jnp.einsum("bqs,bks->bqk", cq, bq)       # (B, q, q)
        y_intra = jnp.einsum("bqk,bqkh,bkhd->bqhd", scores, w, xq)
        # contribution of incoming state
        y_inter = jnp.einsum("bqs,bhsd,bqh->bqhd", cq, h, jnp.exp(cs))
        # new state
        decay_to_end = jnp.exp(total[:, None, :] - cs)    # (B, q, nh)
        s_new = jnp.einsum("bqs,bqhd,bqh->bhsd", bq, xq, decay_to_end)
        h_new = jnp.exp(total)[:, :, None, None] * h + s_new
        return h_new, y_intra + y_inter

    h0 = (init_state if init_state is not None
          else jnp.zeros((b, nh, ns, hd), jnp.float32))
    h_final, ys = lax.scan(step, h0.astype(jnp.float32),
                           (xc.astype(jnp.float32), dtac.astype(jnp.float32),
                            bc.astype(jnp.float32), cc.astype(jnp.float32)),
                           unroll=F.UNROLL)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, nh, hd)
    return y, h_final


def mamba2_fwd(p: Params, x: jax.Array, cfg: ModelConfig, *,
               state: Optional[Params] = None,
               ) -> Tuple[jax.Array, Optional[Params]]:
    """Mamba2 block. state: {"ssm": (B,nh,ns,hd), "conv": (B,w-1,conv_dim)}."""
    b, t, d = x.shape
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd = cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    zxbcdt = apply_linear(p["in_proj"], x)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * ns]
    dt_raw = zxbcdt[..., 2 * di + 2 * ns:]

    if state is None:
        pad = jnp.zeros((b, w - 1, xbc.shape[-1]), xbc.dtype)
        new_conv = xbc[:, t - (w - 1):, :] if t >= w - 1 else None
    else:
        pad = state["conv"]
        new_conv = jnp.concatenate([pad, xbc], 1)[:, -(w - 1):, :]
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    # depthwise causal conv1d, window w
    conv = sum(xbc_pad[:, i: i + t, :] * p["conv_w"][i][None, None]
               for i in range(w)) + p["conv_b"][None, None]
    conv = jax.nn.silu(conv)
    xs = conv[..., :di].reshape(b, t, nh, hd)
    bmat = conv[..., di: di + ns]
    cmat = conv[..., di + ns:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if state is None:
        y, h = _ssd_scan(xs, dt, p["a_log"], bmat, cmat, cfg.ssm_chunk)
        new_state = None if new_conv is None else {"ssm": h, "conv": new_conv}
    else:
        # single-step recurrence (decode)
        a = -jnp.exp(p["a_log"])
        da = jnp.exp(dt[:, 0] * a[None])                  # (B, nh)
        h_prev = state["ssm"].astype(jnp.float32)
        upd = jnp.einsum("bs,bhd,bh->bhsd", bmat[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32), dt[:, 0])
        h = da[:, :, None, None] * h_prev + upd
        y = jnp.einsum("bs,bhsd->bhd", cmat[:, 0].astype(jnp.float32), h)[:, None]
        new_state = {"ssm": h, "conv": new_conv}

    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    return apply_linear(p["out_proj"], y), new_state


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    return {
        "ssm": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def specs_mamba2_state(cfg: ModelConfig, tp: int) -> Params:
    nh = _maybe(cfg.n_ssm_heads, tp, "tp")
    return {"ssm": P("dp", nh, None, None), "conv": P("dp", None, None)}
