"""The full model stack: embeddings -> scanned layer stack -> head.

Heterogeneous depth patterns (DeepSeek's leading dense layers, Jamba's
1-attention-per-8 interleave with MoE every other layer) are handled by a
*stage plan*: an unrolled prefix plus one ``lax.scan`` over super-blocks whose
sub-layer descriptors repeat periodically.  The scan keeps HLO size O(1) in
depth — required to compile 61-88-layer models against 512 host devices.

Entry points:
  init_params / param_specs       -- parameters + logical PartitionSpecs
  forward / forward_embeds        -- full-sequence logits (train & prefill)
  init_cache / cache_specs        -- decode state (KV / latent-KV / SSM)
  decode_step                     -- one-token step with cache
  loss_fn                         -- next-token CE (+ optional MTP aux loss)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = Dict[str, Any]

from repro.models import flags as F

# Back-compat setters (tests/launchers import these from here too).
set_remat = F.set_remat
set_unroll = F.set_unroll


def _maybe_remat(fn):
    if F.REMAT == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if F.REMAT == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str   # attn | ssm
    ffn: str    # mlp | moe | none


def layer_descs(cfg: ModelConfig) -> List[LayerDesc]:
    kinds = cfg.layer_kinds()
    out = []
    for i in range(cfg.n_layers):
        if cfg.layer_has_moe(i):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "mlp"
        else:
            ffn = "none"
        out.append(LayerDesc(kinds[i], ffn))
    return out


def stage_plan(cfg: ModelConfig) -> Tuple[List[LayerDesc], List[LayerDesc], int]:
    """(prefix descs, period descs, n_blocks): layers = prefix + period*n."""
    descs = layer_descs(cfg)
    npre = cfg.first_dense_layers
    rest = descs[npre:]
    if not rest:
        return descs, [], 0
    for p in range(1, len(rest) + 1):
        if len(rest) % p == 0 and rest == rest[:p] * (len(rest) // p):
            return descs[:npre], rest[:p], len(rest) // p
    return descs[:npre], rest, 1


# ---------------------------------------------------------------------------
# per-layer init / specs / fwd
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, desc: LayerDesc, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if desc.kind == "attn":
        p["mixer"] = (L.init_mla(k1, cfg, dtype) if cfg.use_mla
                      else L.init_attention(k1, cfg, dtype))
    else:
        p["mixer"] = L.init_mamba2(k1, cfg, dtype)
    if desc.ffn != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = (L.init_moe(k2, cfg, dtype) if desc.ffn == "moe"
                    else L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype))
    return p


def _layer_specs(cfg: ModelConfig, desc: LayerDesc, tp: int) -> Params:
    p: Params = {"ln1": P(None)}
    if desc.kind == "attn":
        p["mixer"] = (L.specs_mla(cfg, tp) if cfg.use_mla
                      else L.specs_attention(cfg, tp))
    else:
        p["mixer"] = L.specs_mamba2(cfg, tp)
    if desc.ffn != "none":
        p["ln2"] = P(None)
        p["ffn"] = (L.specs_moe(cfg, tp) if desc.ffn == "moe"
                    else L.specs_mlp(cfg.d_ff, cfg.mlp_act, tp))
    return p


def _layer_fwd(cfg: ModelConfig, desc: LayerDesc, p: Params, x: jax.Array,
               positions: jax.Array, cache: Optional[Params],
               cur_len) -> Tuple[jax.Array, Optional[Params]]:
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if desc.kind == "attn":
        fwd = L.mla_fwd if cfg.use_mla else L.attention_fwd
        mix, new_cache = fwd(p["mixer"], h, positions, cfg,
                             cache=cache, cur_len=cur_len)
    else:
        mix, new_cache = L.mamba2_fwd(p["mixer"], h, cfg, state=cache)
    x = x + mix
    x = constrain(x, "dp", "sp", None)
    if desc.ffn != "none":
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        y = (L.moe_fwd(p["ffn"], h2, cfg) if desc.ffn == "moe"
             else L.mlp_fwd(p["ffn"], h2, cfg.mlp_act))
        x = x + y
        x = constrain(x, "dp", "sp", None)
    return x, new_cache


def _layer_cache(cfg: ModelConfig, desc: LayerDesc, batch: int, max_len: int,
                 dtype) -> Optional[Params]:
    if desc.kind == "attn":
        if cfg.use_mla:
            return L.init_mla_cache(cfg, batch, max_len, dtype)
        return L.init_attention_cache(cfg, batch, max_len, dtype)
    return L.init_mamba2_state(cfg, batch, dtype)


def _layer_cache_specs(cfg: ModelConfig, desc: LayerDesc, tp: int) -> Params:
    if desc.kind == "attn":
        if cfg.use_mla:
            return L.specs_mla_cache(cfg, tp)
        return L.specs_attention_cache(cfg, tp)
    return L.specs_mamba2_state(cfg, tp)


# ---------------------------------------------------------------------------
# whole-model init / specs
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = _dtype(cfg)
    prefix, period, nblocks = stage_plan(cfg)
    kemb, khead, kpre, kstk, kmtp = jax.random.split(key, 5)
    params: Params = {
        "embed": (jax.random.truncated_normal(
            kemb, -2, 2, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(khead, cfg.d_model, cfg.vocab, dtype)
    params["prefix"] = [
        _init_layer(k, cfg, d, dtype)
        for k, d in zip(jax.random.split(kpre, max(len(prefix), 1)), prefix)]
    if nblocks:
        def one_block(k):
            ks = jax.random.split(k, len(period))
            return {f"sub{j}": _init_layer(ks[j], cfg, period[j], dtype)
                    for j in range(len(period))}
        blocks = [one_block(k) for k in jax.random.split(kstk, nblocks)]
        params["stack"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    else:
        params["stack"] = {}
    if cfg.mtp_depth:
        km1, km2, km3 = jax.random.split(kmtp, 3)
        params["mtp"] = {
            "proj": L.dense_init(km1, 2 * cfg.d_model, cfg.d_model, dtype),
            "block": _init_layer(km2, cfg, LayerDesc("attn", "mlp"), dtype),
            "norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


def param_specs(cfg: ModelConfig, tp: int) -> Params:
    prefix, period, nblocks = stage_plan(cfg)
    vshard = "tp" if cfg.vocab % max(tp, 1) == 0 else None
    specs: Params = {
        "embed": P(vshard, "fsdp"),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("fsdp", vshard)
    specs["prefix"] = [_layer_specs(cfg, d, tp) for d in prefix]
    if nblocks:
        block = {f"sub{j}": _layer_specs(cfg, period[j], tp)
                 for j in range(len(period))}
        specs["stack"] = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), block,
            is_leaf=lambda s: isinstance(s, P))
    else:
        specs["stack"] = {}
    if cfg.mtp_depth:
        specs["mtp"] = {
            "proj": P("fsdp", None),
            "block": _layer_specs(cfg, LayerDesc("attn", "mlp"), tp),
            "norm": P(None),
        }
    return specs


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _stack_fwd(params: Params, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array, cache: Optional[Params],
               cur_len) -> Tuple[jax.Array, Optional[Params]]:
    prefix, period, nblocks = stage_plan(cfg)
    new_cache: Params = {"prefix": [], "stack": {}}
    for i, desc in enumerate(prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc = _layer_fwd(cfg, desc, params["prefix"][i], x, positions, c, cur_len)
        new_cache["prefix"].append(nc)
    if nblocks:
        if cache is None:
            def body(h, pslice):
                for j, desc in enumerate(period):
                    h, _ = _layer_fwd(cfg, desc, pslice[f"sub{j}"], h,
                                      positions, None, None)
                return h, None
            if F.UNROLL:
                body = _maybe_remat(body)
                for bi in range(nblocks):
                    x, _ = body(x, jax.tree.map(lambda a: a[bi], params["stack"]))
            else:
                x, _ = lax.scan(_maybe_remat(body), x, params["stack"])
        else:
            def body(h, slc):
                pslice, cslice = slc
                ncs = {}
                for j, desc in enumerate(period):
                    h, nc = _layer_fwd(cfg, desc, pslice[f"sub{j}"], h,
                                       positions, cslice[f"sub{j}"], cur_len)
                    ncs[f"sub{j}"] = nc
                return h, ncs
            if F.UNROLL:
                outs = []
                for bi in range(nblocks):
                    x, nc = body(x, jax.tree.map(
                        lambda a: a[bi], (params["stack"], cache["stack"])))
                    outs.append(nc)
                new_cache["stack"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *outs)
            else:
                x, new_stack = lax.scan(body, x, (params["stack"], cache["stack"]))
                new_cache["stack"] = new_stack
    return x, (new_cache if cache is not None else None)


def hidden_embeds(params: Params, embeds: jax.Array, cfg: ModelConfig, *,
                  positions: Optional[jax.Array] = None,
                  cache: Optional[Params] = None,
                  cur_len=None) -> Tuple[jax.Array, Optional[Params]]:
    """embeds: (B, T, D) -> (final hidden states (B, T, D), new cache)."""
    b, t, _ = embeds.shape
    if positions is None:
        if cur_len is not None:
            positions = jnp.broadcast_to(cur_len, (b, t))
        else:
            positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    x = constrain(embeds, "dp", "sp", None)
    x, new_cache = _stack_fwd(params, cfg, x, positions, cache, cur_len)
    return x, new_cache


def _head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.apply_linear(head, x)
    return constrain(logits, "dp", None, "tp")


def forward_embeds(params: Params, embeds: jax.Array, cfg: ModelConfig, *,
                   positions: Optional[jax.Array] = None,
                   cache: Optional[Params] = None,
                   cur_len=None) -> Tuple[jax.Array, Optional[Params]]:
    """embeds: (B, T, D) -> (logits (B, T, V), new cache)."""
    x, new_cache = hidden_embeds(params, embeds, cfg, positions=positions,
                                 cache=cache, cur_len=cur_len)
    return _head(params, cfg, x), new_cache


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, *,
            cache: Optional[Params] = None,
            cur_len=None) -> Tuple[jax.Array, Optional[Params]]:
    """tokens: (B, T) int32 -> (logits, new cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    return forward_embeds(params, x, cfg, cache=cache, cur_len=cur_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = _dtype(cfg)
    prefix, period, nblocks = stage_plan(cfg)
    cache: Params = {
        "prefix": [_layer_cache(cfg, d, batch, max_len, dtype) for d in prefix],
        "stack": {},
    }
    if nblocks:
        block = {f"sub{j}": _layer_cache(cfg, period[j], batch, max_len, dtype)
                 for j in range(len(period))}
        cache["stack"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nblocks,) + x.shape), block)
    return cache


def cache_specs(cfg: ModelConfig, tp: int) -> Params:
    prefix, period, nblocks = stage_plan(cfg)
    specs: Params = {
        "prefix": [_layer_cache_specs(cfg, d, tp) for d in prefix],
        "stack": {},
    }
    if nblocks:
        block = {f"sub{j}": _layer_cache_specs(cfg, period[j], tp)
                 for j in range(len(period))}
        specs["stack"] = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), block,
            is_leaf=lambda s: isinstance(s, P))
    return specs


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Params, cur_len) -> Tuple[jax.Array, Params]:
    """One decode step.  tokens: (B, 1); cur_len: () int32 current length."""
    logits, new_cache = forward(params, tokens, cfg, cache=cache, cur_len=cur_len)
    return logits[:, -1], new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def loss_fn(params: Params, tokens: jax.Array, labels: jax.Array,
            cfg: ModelConfig, *, embeds: Optional[jax.Array] = None) -> jax.Array:
    """Next-token CE; DeepSeek-style MTP aux head adds a 2-ahead term."""
    if embeds is None:
        embeds = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
        use_mtp = bool(cfg.mtp_depth)
    else:
        use_mtp = False
    h, _ = hidden_embeds(params, embeds, cfg)
    logits = _head(params, cfg, h)
    loss = _xent(logits, labels)
    if use_mtp:
        # Predict labels[t+1] from (h_t, emb(labels_t)): one extra block.
        nxt = jnp.take(params["embed"], labels, axis=0).astype(_dtype(cfg))
        z = jnp.concatenate([L.rms_norm(h, params["mtp"]["norm"], cfg.norm_eps),
                             nxt], axis=-1)
        z = L.apply_linear(params["mtp"]["proj"], z)
        b, t, _ = z.shape
        pos = jnp.broadcast_to(jnp.arange(t), (b, t))
        z, _ = _layer_fwd(cfg, LayerDesc("attn", "mlp"), params["mtp"]["block"],
                          z, pos, None, None)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = L.apply_linear(head, z)
        mtp_labels = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
        loss = loss + 0.3 * _xent(mtp_logits, mtp_labels)
    return loss
