"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / hybrid(SSM+attn) / pure-SSM /
encoder-only / VLM-backbone transformers.  Family-specific fields are simply
unused by families that don't need them.  ``src/repro/configs/<arch>.py``
instantiates these with the exact published sizes plus a reduced smoke config.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.types import DENSE, SparsityConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int
    # --- attention ---
    n_heads: int = 0                 # 0 => attention-free (pure SSM)
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    causal: bool = True
    rope_theta: float = 10000.0
    # --- MLA (DeepSeek multi-head latent attention) ---
    use_mla: bool = False
    q_lora_rank: int = 0             # 0 => no query compression
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # --- FFN ---
    d_ff: int = 0
    mlp_act: str = "swiglu"          # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0               # 0 => dense FFN everywhere
    top_k: int = 0
    moe_d_ff: int = 0                # expert intermediate size
    n_shared_experts: int = 0
    moe_period: int = 1              # MoE every k-th layer (jamba: 2)
    first_dense_layers: int = 0      # leading dense layers (deepseek: 3)
    # --- SSM / Mamba2 ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_period: int = 0             # hybrid: 1 attention layer per period (jamba: 8)
    # --- multi-token prediction (deepseek) ---
    mtp_depth: int = 0
    # --- sparsity (the paper's technique, applied to the weights) ---
    sparsity: SparsityConfig = DENSE
    # --- misc ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ----- derived -----
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind ('attn' | 'ssm') for the stack."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.family == "hybrid":
            # 1 attention layer per ``attn_period`` (jamba: index 4 of each
            # 8-layer block holds the attention layer; we use last-of-period).
            return tuple(
                "attn" if (i % self.attn_period) == self.attn_period - 1 else "ssm"
                for i in range(self.n_layers))
        return tuple("attn" for _ in range(self.n_layers))

    def layer_has_moe(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_dense_layers:
            return False
        return (i % self.moe_period) == 0 if self.moe_period > 1 else True

    def num_params(self) -> int:
        """Approximate parameter count (embeddings + stack), for rooflines."""
        p = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.layer_kinds()[i]
            if kind == "attn":
                if self.use_mla:
                    qd = self.q_lora_rank or self.d_model
                    p += self.d_model * self.q_lora_rank if self.q_lora_rank else 0
                    p += qd * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    p += self.d_model * (self.kv_lora_rank + self.qk_rope_head_dim)
                    p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    p += self.n_heads * self.v_head_dim * self.d_model
                else:
                    hd = self.head_dim or self.d_model // self.n_heads
                    p += self.d_model * hd * (self.n_heads + 2 * self.n_kv_heads)
                    p += self.n_heads * hd * self.d_model
            else:
                di, ns = self.d_inner, self.ssm_state
                nh = self.n_ssm_heads
                p += self.d_model * (2 * di + 2 * ns + nh)  # in_proj(z,x) + B,C + dt
                p += di * self.ssm_conv_width + 2 * nh      # conv + A,D
                p += di * self.d_model                      # out_proj
            if self.layer_has_moe(i):
                e, dff = self.n_experts, self.moe_d_ff or self.d_ff
                p += self.d_model * e                       # router
                p += e * 3 * self.d_model * dff
                p += self.n_shared_experts * 3 * self.d_model * dff
            elif kind == "attn" or self.family in ("hybrid",):
                if self.d_ff:
                    mult = 3 if self.mlp_act == "swiglu" else 2
                    p += mult * self.d_model * self.d_ff
            p += 2 * self.d_model                           # norms
        return p

    def active_params(self) -> int:
        """Params touched per token (MoE: only top-k experts) — for 6*N*D."""
        if self.n_experts == 0:
            return self.num_params()
        p = self.num_params()
        # subtract inactive expert params
        dff = self.moe_d_ff or self.d_ff
        n_moe_layers = sum(self.layer_has_moe(i) for i in range(self.n_layers))
        inactive = n_moe_layers * (self.n_experts - self.top_k) * 3 * self.d_model * dff
        return p - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per-arch shapes)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
