"""The *lowering* method (paper Section 2.2) — the baselines Escoin beats.

``im2col`` duplicates each input element up to R*S times into a
(C*R*S, E*F) matrix so convolution becomes one GEMM.  Two baseline paths:

  lowered_dense_conv -- im2col + dense GEMM on zero-filled weights
                        (the CUBLAS baseline of Figs. 8/9/11)
  lowered_sparse_conv-- im2col + CSR(ELL) SpMM on compressed weights
                        (the CUSPARSE baseline)

Both are faithful to the paper's measurement setup: the *same* pruned weights,
differing only in storage format and compute routine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sparse_format import EllMatrix
from repro.core.sparse_linear import ell_matmul


def im2col(x: jax.Array, r: int, s: int, *, stride: int = 1,
           padding: int = 0) -> jax.Array:
    """Lower (N, C, H, W) input to the duplicated (N, E*F, C*R*S) matrix.

    Uses XLA's patch extraction; element order along the last axis is
    (c, r, s) row-major, matching a (M, C*R*S) reshape of OIHW weights.
    """
    patches = lax.conv_general_dilated_patches(
        x, filter_shape=(r, s), window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    n, crs, e, f = patches.shape
    return patches.reshape(n, crs, e * f).transpose(0, 2, 1)


def lowered_dense_conv(x: jax.Array, w_dense: jax.Array, *, stride: int = 1,
                       padding: int = 0) -> jax.Array:
    """CUBLAS analogue: im2col + dense GEMM (weights stored dense, zeros kept)."""
    m, c, r, s = w_dense.shape
    cols = im2col(x, r, s, stride=stride, padding=padding)   # (N, EF, CRS)
    wmat = w_dense.reshape(m, c * r * s)
    out = jnp.einsum("npk,mk->nmp", cols, wmat,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    n = x.shape[0]
    e = (x.shape[2] + 2 * padding - r) // stride + 1
    f = (x.shape[3] + 2 * padding - s) // stride + 1
    return out.reshape(n, m, e, f)


def lowered_sparse_conv(x: jax.Array, ell2d: EllMatrix, r: int, s: int, *,
                        stride: int = 1, padding: int = 0) -> jax.Array:
    """CUSPARSE analogue: im2col + CSR SpMM.

    ``ell2d`` is the (M, C*R*S) reshape of the pruned filter bank in ELL form
    (rectangularised CSR).  The duplicated ``cols`` matrix is materialised in
    full — exactly the bandwidth waste the paper's direct method removes.
    """
    m, crs = ell2d.shape
    cols = im2col(x, r, s, stride=stride, padding=padding)   # (N, EF, CRS)
    out = ell_matmul(cols, ell2d)                            # (N, EF, M)
    n = x.shape[0]
    e = (x.shape[2] + 2 * padding - r) // stride + 1
    f = (x.shape[3] + 2 * padding - s) // stride + 1
    return out.transpose(0, 2, 1).reshape(n, m, e, f)
