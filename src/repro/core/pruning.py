"""Magnitude weight pruning (Han et al. lineage, as used by the paper).

The paper consumes *already pruned* models (SkimCaffe checkpoints).  This
module is the substrate that produces such models inside the framework:
deterministic magnitude pruning, either unstructured (element threshold) or
block-structured (tile L2 norm threshold, for the MXU-friendly BCSR path).

All functions are pure and jit-able; thresholds are computed with
``jnp.quantile`` so the resulting sparsity is exact up to ties.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import SparsityConfig


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero out the ``sparsity`` fraction of smallest-|w| entries."""
    if sparsity <= 0.0:
        return w
    flat = jnp.abs(w).reshape(-1).astype(jnp.float32)
    thresh = jnp.quantile(flat, sparsity)
    return jnp.where(jnp.abs(w) > thresh, w, jnp.zeros_like(w))


def block_prune(w: jax.Array, sparsity: float, block: Tuple[int, int]) -> jax.Array:
    """Prune a 2-D weight at tile granularity by tile L2 norm.

    The weight is padded up to a multiple of the block shape, scored per tile,
    and the lowest-norm ``sparsity`` fraction of tiles is zeroed whole.
    Surviving tiles stay fully dense -> each maps to one MXU matmul.
    """
    if sparsity <= 0.0:
        return w
    if w.ndim != 2:
        raise ValueError(f"block_prune expects 2-D weights, got shape {w.shape}")
    bm, bn = block
    m, n = w.shape
    pm, pn = (-m) % bm, (-n) % bn
    wp = jnp.pad(w, ((0, pm), (0, pn)))
    gm, gn = wp.shape[0] // bm, wp.shape[1] // bn
    tiles = wp.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)  # (gm, gn, bm, bn)
    scores = jnp.sqrt(jnp.sum(jnp.square(tiles.astype(jnp.float32)), axis=(2, 3)))
    thresh = jnp.quantile(scores.reshape(-1), sparsity)
    keep = scores > thresh  # (gm, gn)
    tiles = tiles * keep[:, :, None, None].astype(tiles.dtype)
    wp = tiles.transpose(0, 2, 1, 3).reshape(gm * bm, gn * bn)
    return wp[:m, :n]


def block_prune_conv(w: jax.Array, sparsity: float,
                     block: Tuple[int, int]) -> jax.Array:
    """Prune an (M, C, R, S) filter bank at tile granularity.

    The bank is scored over its flattened (M, C*R*S) weight matrix — the
    layout :class:`~repro.core.sparse_format.BcsrConv` blocks — so every
    surviving tile maps to one dense (bm, bn) MXU contraction in the BCSR
    conv kernel.  Same tile L2-norm threshold rule as :func:`block_prune`.
    """
    if sparsity <= 0.0:
        return w
    if w.ndim != 4:
        raise ValueError(
            f"block_prune_conv expects 4-D filter banks, got shape {w.shape}")
    m = w.shape[0]
    return block_prune(w.reshape(m, -1), sparsity, block).reshape(w.shape)


def prune(w: jax.Array, cfg: SparsityConfig) -> jax.Array:
    """Prune ``w`` according to ``cfg`` (dispatching on method/structure)."""
    if not cfg.enabled or cfg.sparsity <= 0.0:
        return w
    if cfg.method == "bcsr-mxu" and w.ndim == 2:
        return block_prune(w, cfg.sparsity, cfg.block)
    if cfg.method == "bcsr-mxu" and w.ndim == 4:
        return block_prune_conv(w, cfg.sparsity, cfg.block)
    return magnitude_prune(w, cfg.sparsity)


def measured_sparsity(w: jax.Array) -> jax.Array:
    """Fraction of exact zeros (diagnostic; used in tests and benchmarks)."""
    return jnp.mean((w == 0).astype(jnp.float32))
