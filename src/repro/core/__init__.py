"""Escoin core: sparse formats, pruning, and the paper's direct sparse conv."""
from repro.core.types import DENSE, METHODS, SparsityConfig, escoin
from repro.core.pruning import (block_prune, block_prune_conv,
                                magnitude_prune, measured_sparsity, prune)
from repro.core.sparse_format import (
    BcsrConv, BcsrMatrix, EllConv, EllMatrix, balance_ell_conv,
    bcsr_conv_from_dense, bcsr_conv_to_dense, bcsr_from_dense,
    bcsr_to_dense, csr_arrays_from_dense, dequantize, ell_from_dense,
    ell_from_dense_conv, ell_to_dense, inverse_permutation,
    quantize_values, QUANT_DTYPES, stretch_offsets)
from repro.core.direct_conv import dense_conv, direct_sparse_conv, out_spatial
from repro.core.sparse_linear import bcsr_matmul, dense_matmul, ell_matmul
from repro.core.lowering import im2col, lowered_dense_conv, lowered_sparse_conv

__all__ = [
    "DENSE", "METHODS", "SparsityConfig", "escoin",
    "block_prune", "block_prune_conv", "magnitude_prune",
    "measured_sparsity", "prune",
    "BcsrConv", "BcsrMatrix", "EllConv", "EllMatrix", "balance_ell_conv",
    "bcsr_conv_from_dense", "bcsr_conv_to_dense",
    "bcsr_from_dense", "bcsr_to_dense", "csr_arrays_from_dense",
    "dequantize", "ell_from_dense", "ell_from_dense_conv", "ell_to_dense",
    "inverse_permutation", "quantize_values", "QUANT_DTYPES",
    "stretch_offsets",
    "dense_conv", "direct_sparse_conv", "out_spatial",
    "bcsr_matmul", "dense_matmul", "ell_matmul",
    "im2col", "lowered_dense_conv", "lowered_sparse_conv",
]
