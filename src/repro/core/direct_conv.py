"""Direct sparse convolution — the paper's Algorithm 2, in pure JAX.

The computation (paper Eq. 1 restricted to nonzero weights):

    out[n, m, h, w] += value[m, k] * in_pad[n, c[m,k], h*stride + r[m,k],
                                                      w*stride + s[m,k]]

i.e. for every nonzero weight we multiply a *dense, contiguous* window of the
input and accumulate into the output — no im2col materialisation, no input
duplication.  The GPU kernel's warp-over-``w`` coalescing becomes, here, a
whole (E, F) window per nonzero: a dynamic-start static-stride slice, which
XLA lowers to a gather + vectorised FMA.  This function doubles as the
jit-able CPU-measurable implementation *and* the semantic reference for the
Pallas TPU kernel (which additionally tiles it for VMEM).

``lax.scan`` over the K (padded nnz-per-filter) axis keeps the HLO size
independent of sparsity; padding entries multiply by value 0 and are inert.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sparse_format import EllConv


def out_spatial(h: int, w: int, r: int, s: int, stride: int,
                padding: int) -> Tuple[int, int]:
    e = (h + 2 * padding - r) // stride + 1
    f = (w + 2 * padding - s) // stride + 1
    return e, f


def direct_sparse_conv(x: jax.Array, ell: EllConv, *, stride: int = 1,
                       padding: int = 0, unroll: int = 1,
                       accum_dtype=jnp.float32) -> jax.Array:
    """Direct sparse convolution.

    Args:
      x:    (N, C, H, W) input feature maps.
      ell:  stretched-CSR / ELL filter bank for an (M, C, R, S) weight.
      stride, padding: symmetric spatial conv parameters.
      unroll: scan unroll factor (kernel-customisation knob).

    Returns:
      (N, M, E, F) output feature maps, in ``x.dtype``.
    """
    n, c, h, w = x.shape
    m, cw, r, s = ell.shape
    if cw != c:
        raise ValueError(f"input has C={c} but filters expect C={cw}")
    e, f = out_spatial(h, w, r, s, stride, padding)
    # pad_in (paper Fig. 9): one explicit pad instead of per-access bounds tests.
    xpad = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    # Extended window so a static [::stride] after a dynamic-start slice lands
    # exactly on the E (resp. F) output positions.
    e_ext = (e - 1) * stride + 1
    f_ext = (f - 1) * stride + 1

    def slice_one(cix, rix, six):
        win = lax.dynamic_slice(xpad, (0, cix, rix, six), (n, 1, e_ext, f_ext))
        return win[:, 0, ::stride, ::stride]  # (N, E, F)

    def step(out, xs):
        val_k, c_k, r_k, s_k = xs
        win = jax.vmap(slice_one)(c_k, r_k, s_k)           # (M, N, E, F)
        return out + val_k[:, None, None, None].astype(accum_dtype) * win.astype(accum_dtype), None

    out0 = jnp.zeros((m, n, e, f), dtype=accum_dtype)
    xs = (ell.value.T, ell.cidx.T, ell.ridx.T, ell.sidx.T)
    out, _ = lax.scan(step, out0, xs, unroll=unroll)
    return out.transpose(1, 0, 2, 3).astype(x.dtype)


def dense_conv(x: jax.Array, w: jax.Array, *, stride: int = 1,
               padding: int = 0) -> jax.Array:
    """Dense oracle: XLA's native convolution on (zero-filled) dense weights.

    This is the CUBLAS-analogue baseline *and* the correctness oracle for both
    the pure-JAX direct path above and the Pallas kernel.
    """
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
