"""Shared dataclasses for the sparsity subsystem.

Escoin/Escort turns weight pruning into inference speed.  Everything the
framework does with sparsity is driven by a single ``SparsityConfig`` that is
threaded from the arch config down to the individual linear / conv call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Sparse execution methods.
#   dense       : zero-filled dense weights, XLA native ops  (CUBLAS analogue)
#   lowered     : im2col + CSR SpMM                           (CUSPARSE analogue)
#   csr-direct  : the paper's direct sparse convolution / ELL sparse matmul
#   bcsr-mxu    : beyond-paper block-sparse path that feeds the TPU MXU
METHODS = ("dense", "lowered", "csr-direct", "bcsr-mxu")


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """How a weight tensor is pruned and executed.

    Attributes:
      sparsity: fraction of weights that are zero (paper: typically >= 0.8).
      method:   one of ``METHODS``.
      block:    (bm, bn) tile size for the ``bcsr-mxu`` path.  Tiles are scored
                by L2 norm and pruned whole, so surviving tiles are dense and
                MXU-friendly.  128x128 aligns with the systolic array; smaller
                blocks trade MXU utilisation for pruning flexibility.
      enabled:  master switch; ``False`` means the layer runs dense regardless.
    """

    sparsity: float = 0.0
    method: str = "dense"
    block: Tuple[int, int] = (128, 128)
    enabled: bool = False

    def __post_init__(self) -> None:
        if self.method not in METHODS:
            raise ValueError(f"unknown sparsity method {self.method!r}; choose from {METHODS}")
        if not (0.0 <= self.sparsity < 1.0):
            raise ValueError(f"sparsity must be in [0, 1), got {self.sparsity}")

    @property
    def density(self) -> float:
        return 1.0 - self.sparsity


DENSE = SparsityConfig()


def escoin(sparsity: float = 0.9, method: str = "csr-direct",
           block: Tuple[int, int] = (128, 128)) -> SparsityConfig:
    """Convenience constructor for an enabled sparsity config."""
    return SparsityConfig(sparsity=sparsity, method=method, block=block, enabled=True)
