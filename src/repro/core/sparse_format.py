"""Compressed sparse formats with the paper's *weight stretching* preprocessing.

Three formats:

``EllConv``   -- the paper's stretched-CSR conv weights, padded per-row to a
                 rectangular (ELL) layout so shapes are static under jit.  Each
                 output channel m keeps K = max-row-nnz entries of
                 (value, c, r, s, stretched offset).  Padding entries carry
                 value 0 and index 0, so they are mathematically inert.

``EllMatrix`` -- the same idea for 2-D weights (sparse linear layers); each row
                 keeps K column indices + values.

``BcsrMatrix``-- block compressed sparse row for the MXU path: per block-row,
                 a padded list of nonzero block-column ids plus the dense tile
                 data.  Zero-padded tiles point at block-column 0 with all-zero
                 data (inert).

Conversion happens once at model-load time on the host (numpy), exactly like
the paper's one-shot CSR construction + weight stretching; the jit-side
consumers only ever see fixed-shape arrays.

The conv formats (``EllConv``/``BcsrConv``) additionally support *quantised
value streams* (:func:`quantize_values` / :func:`dequantize`): the nonzero
values stored int8 or fp8 (``float8_e4m3fn``) with one f32 symmetric scale
per output channel, so the dominant HBM traffic of the sparse kernels
shrinks 4x while accumulation stays f32.  See the helper docstrings for the
round-trip error bounds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# ELL conv format (paper's stretched CSR, rectangularised)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EllConv:
    """Sparse conv weights for a (M, C, R, S) filter bank.

    value:  (M, K) float   -- nonzero weights, zero-padded per row
    cidx:   (M, K) int32   -- input-channel index of each nonzero
    ridx:   (M, K) int32   -- filter-row index
    sidx:   (M, K) int32   -- filter-col index
    offset: (M, K) int32   -- *stretched* flat offset  c*Hp*Wp + r*Wp + s for a
                              padded input of shape (C, Hp, Wp); recomputed per
                              layer geometry by ``stretch_offsets``.
    nnz:    (M,)   int32   -- true row lengths (kernel loop bounds + balance)
    shape:  original (M, C, R, S)
    perm:   optional (M,) int32 -- row permutation of an *nnz-balanced* bank
                              (``balance_ell_conv``): row i of this bank is
                              output channel ``perm[i]`` of the original
                              filter bank.  None for banks in natural channel
                              order.  Consumers (``kernels.sparse_conv.ops``)
                              apply the inverse permutation to the output and
                              the forward permutation to bias/residual, so the
                              reordering is invisible outside the kernel.
    scale:  optional (M,) f32 -- per-output-channel symmetric dequantisation
                              scale of a *quantised* bank
                              (:func:`quantize_values`): the semantic weight
                              is ``value[m, j] * scale[m]`` in f32.  None for
                              banks whose values are stored at full width.
    """

    value: jax.Array
    cidx: jax.Array
    ridx: jax.Array
    sidx: jax.Array
    offset: jax.Array
    nnz: jax.Array
    shape: Tuple[int, int, int, int]
    perm: Optional[jax.Array] = None
    scale: Optional[jax.Array] = None

    @property
    def k(self) -> int:
        return int(self.value.shape[1])

    @property
    def value_dtype(self) -> str:
        """Canonical storage dtype name of the value stream (e.g. "float32",
        "int8", "float8_e4m3fn")."""
        return jnp.dtype(self.value.dtype).name

    def tree_flatten(self):
        return (self.value, self.cidx, self.ridx, self.sidx, self.offset,
                self.nnz, self.perm, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        value, cidx, ridx, sidx, offset, nnz, perm, scale = leaves
        return cls(value, cidx, ridx, sidx, offset, nnz, shape, perm, scale)


jax.tree_util.register_pytree_node(
    EllConv, EllConv.tree_flatten, EllConv.tree_unflatten)


def ell_from_dense_conv(w, pad_to: int = 8, balance: bool = False) -> EllConv:
    """Convert a dense (M, C, R, S) filter bank to ``EllConv``.

    ``pad_to`` rounds K up so jit specialisations are shared across layers with
    similar density (the paper's 'kernel customization' table keys on this).
    K is clamped to ``K >= pad_to >= 1`` even for a fully-pruned (all-zero)
    filter bank, so the Pallas path never sees zero-width value arrays.
    ``balance=True`` additionally sorts output channels by row nnz
    (``balance_ell_conv``) and records the permutation in ``perm``.
    """
    w = np.asarray(w)
    m, c, r, s = w.shape
    if m == 0:
        raise ValueError("ell_from_dense_conv needs at least one output channel")
    pad_to = max(1, int(pad_to))
    rows_val, rows_c, rows_r, rows_s, nnz = [], [], [], [], []
    for i in range(m):
        ci, ri, si = np.nonzero(w[i])
        rows_val.append(w[i, ci, ri, si])
        rows_c.append(ci)
        rows_r.append(ri)
        rows_s.append(si)
        nnz.append(len(ci))
    k = max(1, max(nnz))
    k = max(pad_to, ((k + pad_to - 1) // pad_to) * pad_to)
    val = np.zeros((m, k), dtype=w.dtype)
    cid = np.zeros((m, k), dtype=np.int32)
    rid = np.zeros((m, k), dtype=np.int32)
    sid = np.zeros((m, k), dtype=np.int32)
    for i in range(m):
        n = nnz[i]
        val[i, :n] = rows_val[i]
        cid[i, :n] = rows_c[i]
        rid[i, :n] = rows_r[i]
        sid[i, :n] = rows_s[i]
    offset = np.zeros((m, k), dtype=np.int32)  # filled by stretch_offsets
    ell = EllConv(
        value=jnp.asarray(val), cidx=jnp.asarray(cid), ridx=jnp.asarray(rid),
        sidx=jnp.asarray(sid), offset=jnp.asarray(offset),
        nnz=jnp.asarray(np.asarray(nnz, np.int32)), shape=(m, c, r, s))
    return balance_ell_conv(ell) if balance else ell


def stretch_offsets(ell: EllConv, hp: int, wp: int) -> EllConv:
    """The paper's *weight stretching*: bake the layout function
    f(c, r, s) = (c*Hp + r)*Wp + s into the column indices, for a padded input
    of spatial shape (Hp, Wp).  Only ``offset`` changes; run once per geometry.
    """
    off = (ell.cidx * hp + ell.ridx) * wp + ell.sidx
    return dataclasses.replace(ell, offset=off.astype(jnp.int32))


def balance_ell_conv(ell: EllConv) -> EllConv:
    """nnz-balanced channel packing: sort output channels by descending row
    nnz (Yao et al., *Balanced Sparsity*, arXiv:1811.00206 — balancing
    nonzeros across parallel workers).

    After sorting, rows of near-equal length sit adjacently, so every TM-tile
    of the Pallas kernel's channel loop holds rows of near-equal nnz instead
    of being bounded by its single worst row.  The permutation is carried in
    ``perm`` (row i of the balanced bank = original channel ``perm[i]``);
    per-row contents are untouched, so each row's accumulation order — and
    therefore its f32 result — is bit-identical to the unbalanced bank's.

    Pure ``jnp`` (stable argsort + row gathers): callable both host-side at
    format-build time and inside a jit trace.  Balancing an already-balanced
    bank composes the permutations (idempotent in effect: the row order is
    already sorted, so the stable argsort is the identity).
    """
    order = jnp.argsort(-ell.nnz, stable=True).astype(jnp.int32)
    take = lambda a: jnp.take(a, order, axis=0)  # noqa: E731
    perm = take(ell.perm) if ell.perm is not None else order
    return EllConv(
        value=take(ell.value), cidx=take(ell.cidx), ridx=take(ell.ridx),
        sidx=take(ell.sidx), offset=take(ell.offset), nnz=take(ell.nnz),
        shape=ell.shape, perm=perm,
        scale=take(ell.scale) if ell.scale is not None else None)


def inverse_permutation(perm: jax.Array) -> jax.Array:
    """Positions of each original row in a permuted bank: if row i of the
    bank is original channel ``perm[i]``, then ``out[:, inv]`` restores
    natural channel order for an output computed in bank row order."""
    return jnp.argsort(perm).astype(jnp.int32)


# ---------------------------------------------------------------------------
# ELL matrix format (sparse linear layers; CSR rectangularised)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EllMatrix:
    """Sparse (M, N) weight: per row K padded (value, column) pairs."""

    value: jax.Array   # (M, K)
    colidx: jax.Array  # (M, K) int32
    nnz: jax.Array     # (M,) int32
    shape: Tuple[int, int]

    @property
    def k(self) -> int:
        return int(self.value.shape[1])

    def tree_flatten(self):
        return (self.value, self.colidx, self.nnz), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)


jax.tree_util.register_pytree_node(
    EllMatrix, EllMatrix.tree_flatten, EllMatrix.tree_unflatten)


def ell_from_dense(w, pad_to: int = 8) -> EllMatrix:
    w = np.asarray(w)
    if w.ndim != 2:
        raise ValueError(f"ell_from_dense expects 2-D, got {w.shape}")
    m, n = w.shape
    if m == 0:
        raise ValueError("ell_from_dense needs at least one row")
    pad_to = max(1, int(pad_to))
    nnz = (w != 0).sum(axis=1)
    k = max(1, int(nnz.max()))
    k = max(pad_to, ((k + pad_to - 1) // pad_to) * pad_to)
    val = np.zeros((m, k), dtype=w.dtype)
    col = np.zeros((m, k), dtype=np.int32)
    for i in range(m):
        (ci,) = np.nonzero(w[i])
        val[i, : len(ci)] = w[i, ci]
        col[i, : len(ci)] = ci
    return EllMatrix(value=jnp.asarray(val), colidx=jnp.asarray(col),
                     nnz=jnp.asarray(nnz.astype(np.int32)), shape=(m, n))


def ell_to_dense(ell: EllMatrix) -> jax.Array:
    """Inverse of ``ell_from_dense`` (oracle for round-trip property tests).

    Padding entries all carry value 0, so scatter-add is safe even though they
    alias column 0.
    """
    m, n = ell.shape
    out = jnp.zeros((m, n), dtype=ell.value.dtype)
    rows = jnp.arange(m)[:, None] * jnp.ones_like(ell.colidx)
    return out.at[rows, ell.colidx].add(ell.value)


# ---------------------------------------------------------------------------
# BCSR (block compressed sparse row) for the MXU path
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BcsrMatrix:
    """Block-sparse (M, N) weight.

    blocks:   (nbr, KB, bm, bn) -- per block-row, KB padded dense tiles
    blockcol: (nbr, KB) int32   -- block-column id of each tile (0 for padding)
    nblocks:  (nbr,) int32      -- true tiles per block-row
    shape:    original (M, N); block: (bm, bn)
    """

    blocks: jax.Array
    blockcol: jax.Array
    nblocks: jax.Array
    shape: Tuple[int, int]
    block: Tuple[int, int]

    @property
    def kb(self) -> int:
        return int(self.blocks.shape[1])

    def tree_flatten(self):
        return (self.blocks, self.blockcol, self.nblocks), (self.shape, self.block)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        shape, block = aux
        return cls(*leaves, shape=shape, block=block)


jax.tree_util.register_pytree_node(
    BcsrMatrix, BcsrMatrix.tree_flatten, BcsrMatrix.tree_unflatten)


def bcsr_from_dense(w, block: Tuple[int, int] = (128, 128), pad_to: int = 1) -> BcsrMatrix:
    """Convert a (block-pruned) dense matrix to BCSR.

    A tile is kept iff it contains any nonzero.  Rows are padded to a common
    tile count KB so shapes are static; padding tiles are all-zero data at
    block-column 0 (inert).  ``pad_to`` rounds KB up (and is clamped to
    ``>= 1`` like the ELL converters), so an all-zero matrix still carries
    one inert tile per block-row instead of a zero-width array.
    """
    w = np.asarray(w)
    m, n = w.shape
    bm, bn = block
    pad_to = max(1, int(pad_to))
    pm, pn = (-m) % bm, (-n) % bn
    wp = np.pad(w, ((0, pm), (0, pn)))
    gm, gn = wp.shape[0] // bm, wp.shape[1] // bn
    tiles = wp.reshape(gm, bm, gn, bn).transpose(0, 2, 1, 3)  # (gm, gn, bm, bn)
    keep = (tiles != 0).any(axis=(2, 3))                      # (gm, gn)
    counts = keep.sum(axis=1)
    kb = max(1, int(counts.max()))
    kb = ((kb + pad_to - 1) // pad_to) * pad_to
    blocks = np.zeros((gm, kb, bm, bn), dtype=w.dtype)
    bcol = np.zeros((gm, kb), dtype=np.int32)
    for i in range(gm):
        (cols,) = np.nonzero(keep[i])
        blocks[i, : len(cols)] = tiles[i, cols]
        bcol[i, : len(cols)] = cols
    return BcsrMatrix(blocks=jnp.asarray(blocks), blockcol=jnp.asarray(bcol),
                      nblocks=jnp.asarray(counts.astype(np.int32)),
                      shape=(m, n), block=block)


def bcsr_to_dense(b: BcsrMatrix) -> jax.Array:
    m, n = b.shape
    bm, bn = b.block
    gm = b.blocks.shape[0]
    gn = (n + bn - 1) // bn
    out = jnp.zeros((gm, gn, bm, bn), dtype=b.blocks.dtype)
    rows = jnp.arange(gm)[:, None] * jnp.ones_like(b.blockcol)
    out = out.at[rows, b.blockcol].add(b.blocks)
    dense = out.transpose(0, 2, 1, 3).reshape(gm * bm, gn * bn)
    return dense[:m, :n]


def bcsr_stack_from_dense(w3d, block: Tuple[int, int] = (128, 128)) -> BcsrMatrix:
    """Convert a stacked (L, M, N) weight to a stacked BCSR (leading L on
    every leaf) so it can ride through a ``lax.scan`` over layers: slicing the
    leading axis of each leaf yields exactly the per-layer ``BcsrMatrix``.
    Rows are padded to the max tile count across all layers."""
    w3d = np.asarray(w3d)
    per_layer = [bcsr_from_dense(w3d[i], block) for i in range(w3d.shape[0])]
    kb = max(b.kb for b in per_layer)
    blocks, bcol, nb = [], [], []
    for b in per_layer:
        pad = kb - b.kb
        blocks.append(np.pad(np.asarray(b.blocks), ((0, 0), (0, pad), (0, 0), (0, 0))))
        bcol.append(np.pad(np.asarray(b.blockcol), ((0, 0), (0, pad))))
        nb.append(np.asarray(b.nblocks))
    return BcsrMatrix(
        blocks=jnp.asarray(np.stack(blocks)), blockcol=jnp.asarray(np.stack(bcol)),
        nblocks=jnp.asarray(np.stack(nb)),
        shape=per_layer[0].shape, block=block)


# ---------------------------------------------------------------------------
# BCSR conv format (blocked filter banks for the MXU conv path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BcsrConv:
    """Block-sparse conv weights for an (M, C, R, S) filter bank.

    The bank is viewed as its flattened (M, C*R*S) weight matrix — the same
    matrix ``core/lowering.py`` multiplies against im2col patches — and
    blocked with the :class:`BcsrMatrix` tile/pad machinery: per block-row of
    ``bm`` output channels, a padded list of kept (bm, bn) tiles over the
    flattened input-patch axis.  Column ``j`` of a tile at block-column
    ``bc`` covers the original weight entry ``(c, r, s)`` with
    ``bc*bn + j = c*(R*S) + r*S + s``; columns past ``C*R*S`` (the format's
    right-padding) carry zero weights and are inert.

    blocks:   (gbm, KB, bm, bn) -- per block-row, KB padded dense tiles
    blockcol: (gbm, KB) int32   -- block-column id of each tile (0 = padding)
    nblocks:  (gbm,) int32      -- true tiles per block-row
    shape:    original (M, C, R, S); block: (bm, bn)
    scale:    optional (gbm, bm) f32 -- per-output-channel symmetric
              dequantisation scales of a *quantised* bank
              (:func:`quantize_values`), laid out by (block-row, local row)
              so the kernel can block it like the bias; rows past M (the
              channel padding) carry scale 1 and all-zero values (inert).
              None for banks whose tiles are stored at full width.
    """

    blocks: jax.Array
    blockcol: jax.Array
    nblocks: jax.Array
    shape: Tuple[int, int, int, int]
    block: Tuple[int, int]
    scale: Optional[jax.Array] = None

    @property
    def kb(self) -> int:
        return int(self.blocks.shape[1])

    @property
    def gbm(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def value_dtype(self) -> str:
        """Canonical storage dtype name of the tile data (e.g. "float32",
        "int8", "float8_e4m3fn")."""
        return jnp.dtype(self.blocks.dtype).name

    def tree_flatten(self):
        return ((self.blocks, self.blockcol, self.nblocks, self.scale),
                (self.shape, self.block))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        shape, block = aux
        blocks, blockcol, nblocks, scale = leaves
        return cls(blocks, blockcol, nblocks, shape=shape, block=block,
                   scale=scale)


jax.tree_util.register_pytree_node(
    BcsrConv, BcsrConv.tree_flatten, BcsrConv.tree_unflatten)


def bcsr_conv_from_dense(w, block: Tuple[int, int] = (8, 128),
                         pad_to: int = 1) -> BcsrConv:
    """Convert a dense (M, C, R, S) filter bank to :class:`BcsrConv`.

    Delegates to :func:`bcsr_from_dense` on the flattened (M, C*R*S) weight
    matrix, so the tile-keep rule, KB padding and inert zero tiles are
    exactly the linear-layer BCSR ones.  Weights pruned at tile granularity
    (``core.pruning.block_prune_conv``) yield genuinely sparse block rows;
    unstructured-pruned weights degrade gracefully to a dense blocked bank
    (every tile kept) — slower, never wrong.
    """
    w = np.asarray(w)
    if w.ndim != 4:
        raise ValueError(f"bcsr_conv_from_dense expects 4-D, got {w.shape}")
    m, c, r, s = w.shape
    flat = bcsr_from_dense(w.reshape(m, c * r * s), block, pad_to=pad_to)
    return BcsrConv(blocks=flat.blocks, blockcol=flat.blockcol,
                    nblocks=flat.nblocks, shape=(m, c, r, s), block=block)


def bcsr_conv_to_dense(b: BcsrConv) -> jax.Array:
    """Inverse of ``bcsr_conv_from_dense`` (round-trip / parity oracle).

    A quantised bank reconstructs its *semantic* (dequantised f32) weights —
    dense reconstruction is how the oracles and fallbacks consume the bank.
    """
    if b.scale is not None:
        b = dequantize(b)
    m, c, r, s = b.shape
    flat = BcsrMatrix(blocks=b.blocks, blockcol=b.blockcol,
                      nblocks=b.nblocks, shape=(m, c * r * s), block=b.block)
    return bcsr_to_dense(flat).reshape(m, c, r, s)


# ---------------------------------------------------------------------------
# Quantised value streams (int8 / fp8 banks with per-channel f32 scales)
# ---------------------------------------------------------------------------

# Largest magnitude each narrow storage dtype can carry: int8 keeps the
# symmetric [-127, 127] range (never -128, so negation round-trips), fp8
# e4m3fn's max finite value is 448 (the format has no inf; casts saturate).
QUANT_DTYPES = {"int8": 127.0, "float8_e4m3fn": 448.0}


def _quant_scales(absmax: jax.Array, qmax: float) -> jax.Array:
    """Per-channel symmetric scale mapping |w| <= absmax onto [-qmax, qmax].
    All-zero channels get scale 1 so they quantise — and dequantise — to
    exact zeros instead of dividing by zero."""
    absmax = absmax.astype(jnp.float32)
    return jnp.where(absmax > 0, absmax / qmax, 1.0)


def _storage_dtype(value_dtype: str):
    if value_dtype not in QUANT_DTYPES:
        raise ValueError(
            f"unsupported quantised value dtype {value_dtype!r}; "
            f"expected one of {sorted(QUANT_DTYPES)}")
    return jnp.dtype(value_dtype)


def _quantize_array(w: jax.Array, scale: jax.Array, value_dtype: str):
    """Quantise ``w`` (already broadcast-divided by ``scale``) to storage."""
    q = w.astype(jnp.float32) / scale
    if value_dtype == "int8":
        return jnp.clip(jnp.rint(q), -127, 127).astype(jnp.int8)
    return q.astype(jnp.dtype(value_dtype))


def quantize_values(fmt, value_dtype: str = "int8"):
    """Quantise a conv bank's value stream to ``int8`` or ``float8_e4m3fn``.

    Per-output-channel *symmetric* quantisation: channel m's scale is
    ``absmax_m / 127`` (int8) or ``absmax_m / 448`` (fp8), values are stored
    narrow and the f32 scales ride in ``.scale``; the semantic weight is
    ``value * scale`` (for ``BcsrConv``, tile row ``i`` of block-row ``mt``
    uses ``scale[mt, i]``).  The padding entries of either format are zero
    and stay zero.  Quantising an already-quantised bank raises.

    Round-trip error bounds (``dequantize(quantize_values(b)) - b``), per
    channel with scale ``s`` and original weight ``w``:

    * int8 -- round-to-nearest on ``w / s`` in [-127, 127], so
      ``|err| <= s / 2`` (= ``absmax / 254``) elementwise.
    * float8_e4m3fn -- 3 mantissa bits round-to-nearest: relative error
      ``<= 2**-4`` for normal quotients, absolute error ``<= s * 2**-10``
      below the subnormal threshold; combined
      ``|err| <= max(|w| * 2**-4, s * 2**-10)`` (up to f32 rounding of the
      ``w / s`` quotient itself).

    ``test_sparse_formats.py`` property-checks both bounds.
    """
    _storage_dtype(value_dtype)
    qmax = QUANT_DTYPES[value_dtype]
    if isinstance(fmt, EllConv):
        if fmt.scale is not None:
            raise ValueError("bank is already quantised")
        scale = _quant_scales(jnp.abs(fmt.value).max(axis=1), qmax)
        value = _quantize_array(fmt.value, scale[:, None], value_dtype)
        return dataclasses.replace(fmt, value=value, scale=scale)
    if isinstance(fmt, BcsrConv):
        if fmt.scale is not None:
            raise ValueError("bank is already quantised")
        # (gbm, KB, bm, bn) -> per-(block-row, local-row) channel absmax
        scale = _quant_scales(jnp.abs(fmt.blocks).max(axis=(1, 3)), qmax)
        blocks = _quantize_array(
            fmt.blocks, scale[:, None, :, None], value_dtype)
        return dataclasses.replace(fmt, blocks=blocks, scale=scale)
    raise TypeError(f"quantize_values expects EllConv or BcsrConv, "
                    f"got {type(fmt).__name__}")


def dequantize(fmt):
    """Rebuild the f32 value stream of a quantised bank (``value * scale``).
    Unquantised banks pass through unchanged.  The multiply matches the
    kernels' in-register dequantisation exactly — same operands, same f32
    op — so the ELL kernel run on a quantised bank is bit-identical to the
    f32 kernel run on the dequantised bank."""
    if isinstance(fmt, EllConv):
        if fmt.scale is None:
            return fmt
        value = fmt.value.astype(jnp.float32) * fmt.scale[:, None]
        return dataclasses.replace(fmt, value=value, scale=None)
    if isinstance(fmt, BcsrConv):
        if fmt.scale is None:
            return fmt
        blocks = fmt.blocks.astype(jnp.float32) * fmt.scale[:, None, :, None]
        return dataclasses.replace(fmt, blocks=blocks, scale=None)
    raise TypeError(f"dequantize expects EllConv or BcsrConv, "
                    f"got {type(fmt).__name__}")


def csr_arrays_from_dense(w) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Classic CSR triplet (value, colidx, rowptr) — Fig. 4 of the paper.

    Used by the lowered CUSPARSE-analogue baseline and by format round-trip
    tests; not consumed by jit code (ragged).
    """
    w = np.asarray(w)
    m, _ = w.shape
    rowptr = np.zeros(m + 1, dtype=np.int32)
    vals, cols = [], []
    for i in range(m):
        (ci,) = np.nonzero(w[i])
        vals.append(w[i, ci])
        cols.append(ci.astype(np.int32))
        rowptr[i + 1] = rowptr[i] + len(ci)
    value = np.concatenate(vals) if vals else np.zeros(0, w.dtype)
    colidx = np.concatenate(cols) if cols else np.zeros(0, np.int32)
    return value, colidx, rowptr
