"""Sparse linear layers: the paper's mechanism applied to 2-D weights.

A linear layer is the 1x1-convolution special case of Escoin's direct sparse
convolution (R = S = 1, E*F = sequence positions), so the same three execution
strategies exist:

  ell_matmul   -- direct CSR/ELL traversal (paper-faithful; VPU broadcast-FMA)
  bcsr_matmul  -- block-sparse tiles on the MXU (beyond-paper TPU adaptation)
  dense        -- zero-filled dense matmul (CUBLAS-analogue baseline)

All compute ``y = x @ W.T`` for weight ``W`` of logical shape (M, N) and input
``x`` of shape (..., N), matching how the model stack stores projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sparse_format import BcsrMatrix, EllMatrix


def ell_matmul(x: jax.Array, ell: EllMatrix, *, unroll: int = 1,
               accum_dtype=jnp.float32) -> jax.Array:
    """Direct ELL sparse matmul: scan nonzeros, gather-and-FMA.

    Per step k, every output row m pulls one input element x[..., colidx[m,k]]
    and accumulates value[m,k] * it — the 1x1 instance of Algorithm 2.
    """
    m, n = ell.shape
    if x.shape[-1] != n:
        raise ValueError(f"x last dim {x.shape[-1]} != weight N {n}")

    def step(out, xs):
        val_k, col_k = xs                       # (M,), (M,)
        gathered = jnp.take(x, col_k, axis=-1)  # (..., M)
        return out + val_k.astype(accum_dtype) * gathered.astype(accum_dtype), None

    out0 = jnp.zeros(x.shape[:-1] + (m,), dtype=accum_dtype)
    out, _ = lax.scan(step, out0, (ell.value.T, ell.colidx.T), unroll=unroll)
    return out.astype(x.dtype)


def bcsr_matmul(x: jax.Array, b: BcsrMatrix, *, accum_dtype=jnp.float32) -> jax.Array:
    """Block-sparse matmul: gather nonzero input tiles, dense MXU dots.

    y[..., i*bm:(i+1)*bm] = sum_kb  x_tiles[..., blockcol[i,kb], :] @ blocks[i,kb].T
    """
    m, n = b.shape
    bm, bn = b.block
    if x.shape[-1] != n:
        raise ValueError(f"x last dim {x.shape[-1]} != weight N {n}")
    pad_n = (-n) % bn
    xb = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad_n)])
    gn = xb.shape[-1] // bn
    xb = xb.reshape(x.shape[:-1] + (gn, bn))
    # (..., gm, KB, bn): per block-row, the input tiles its nonzero blocks touch.
    gathered = jnp.take(xb, b.blockcol, axis=-2)
    out = jnp.einsum("...gkn,gkmn->...gm", gathered.astype(accum_dtype),
                     b.blocks.astype(accum_dtype),
                     preferred_element_type=accum_dtype)
    out = out.reshape(x.shape[:-1] + (b.blocks.shape[0] * bm,))
    return out[..., :m].astype(x.dtype)


def dense_matmul(x: jax.Array, w: jax.Array, *, accum_dtype=jnp.float32) -> jax.Array:
    """CUBLAS-analogue baseline: zero-filled dense matmul, y = x @ W.T."""
    return jnp.matmul(x, w.T, preferred_element_type=accum_dtype).astype(x.dtype)
