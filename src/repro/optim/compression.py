"""Int8 error-feedback gradient compression for the cross-pod hop.

At 2-pod scale the pod axis crosses DCN (much slower than ICI), so the
cross-pod gradient all-reduce is the term worth compressing.  Scheme:

  1. per-tensor symmetric int8 quantisation with an fp32 scale,
  2. all-reduce the int8 payload (as int32 accumulate) over the pod axis,
  3. dequantise; the quantisation residual is fed back into the next step's
     gradient (error feedback keeps the scheme unbiased over time).

Used inside ``shard_map`` over the "pod" axis by the train step when
``compress_cross_pod=True``; the in-pod reduction stays full precision.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 payload, fp32 scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads: Any, axis_name: str) -> Any:
    """Error-feedback-free single-shot compressed psum over ``axis_name``.

    For each leaf: quantise, psum the int8 payload (accumulated in int32 so
    the reduction cannot overflow), psum the scales, dequantise with the mean
    scale.  Residual feedback is applied by the caller, which keeps the
    residual buffer in the train state.
    """
    n = jax.lax.psum(1.0, axis_name)

    def one(g):
        q, scale = compress_int8(g)
        qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ss = jax.lax.psum(scale, axis_name) / n
        return (qs.astype(jnp.float32) * ss / n).astype(g.dtype)

    return jax.tree.map(one, grads)
