"""AdamW with optional bf16 second-moment state (HBM-limited cells).

Pure functions over pytrees; optimizer state shards exactly like the params
(ZeRO-3: the fsdp axis already splits both), so no extra spec plumbing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # "bfloat16" halves optimizer HBM


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(params: Any, grads: Any, opt_state: Any, cfg: AdamWConfig,
                 lr: jax.Array) -> Tuple[Any, Any, jax.Array]:
    """Returns (new_params, new_opt_state, grad_norm)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
    step = opt_state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** sf
    bc2 = 1.0 - cfg.b2 ** sf
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(dt), v32.astype(dt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
