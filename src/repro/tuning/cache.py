"""Persistent plan cache: tune once per deployment, reload forever.

A plan cache is a small versioned JSON document mapping a *layer key* to the
winning :class:`PlanEntry`.  Keys capture everything the decision depends on —
layer geometry, a bucketed sparsity (so near-equal densities share plans,
like the paper's kernel-customization table), dtype, and backend — and
nothing it doesn't (layer names, model names), so identical layers across
models share one entry.

Format (``docs/autotuning.md`` documents it for humans):

    {"version": 6,
     "entries": {"<key>": {"method": "bsr", "te": 32, "tf": 32,
                           "block_m": 32, "block_n": 128, "fuse": true,
                           "value_dtype": "int8",
                           "est_s": 1.2e-4, "source": "roofline"}}}

Version history: v6 added ``value_dtype`` — the bank's value-storage dtype
("float32", or the quantised "int8"/"float8_e4m3fn" with per-output-channel
f32 scales and f32 accumulation); v5 added the ``bsr`` method (BCSR MXU
conv) and its ``block_m``/``block_n`` tile shape; v4 added the halo DMA
schedule ``pipeline`` (double-buffered staging: cell i+1's input block
copies while cell i computes) and ``permute`` (nnz-balanced bank with the
inverse permutation applied to the output) to pallas entries; v3 added the
``fuse`` flag (in-kernel epilogue: bias / ReLU / bottleneck shortcut
applied to the f32 accumulator); v2 added the output spatial tile
``(te, tf)``.  Older documents load via migration — v1 entries get ``te =
tf = None`` (the untiled schedule the v1 kernel executed), v1/v2 entries
get ``fuse = False`` (those kernels always ran the unfused three-pass
epilogue), v1-v3 entries get ``pipeline = permute = False`` (those kernels
always staged with a blocking single-buffer DMA over natural-order banks),
v1-v4 entries get ``block_m = block_n = None`` (no pre-v5 kernel ran
blocked), and v1-v5 entries get ``value_dtype = "float32"`` (every pre-v6
kernel streamed f32 values) — and are re-persisted as v6 on the next save.
A (corrupt or hand-edited) pre-v5 entry claiming ``method="bsr"``
therefore migrates with no block shape; executors treat that as a stale
plan and fall back to dense.  Likewise a migrated (f32) entry executed
against an already-quantised bank falls back with the
``value_dtype_mismatch`` reason code rather than silently dequantising.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Dict, Optional

from repro.tuning.space import Candidate, ConvGeometry

CACHE_VERSION = 6
# Older schema versions load() can migrate in-memory (see module docstring).
MIGRATABLE_VERSIONS = (1, 2, 3, 4, 5)


class PlanCacheWarning(UserWarning):
    """A plan-cache file could not be loaded (or was partially dropped) and
    the deployment continues on an empty/reduced cache instead."""

# Sparsity bucket width for cache keys: layers within 5% density share plans.
SPARSITY_BUCKET = 0.05


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """The winning customization for one layer key."""

    method: str
    tm: Optional[int] = None
    pad_to: Optional[int] = None
    te: Optional[int] = None      # output spatial tile (None: untiled)
    tf: Optional[int] = None
    fuse: bool = False            # pallas/bsr: in-kernel epilogue
    pipeline: bool = False        # pallas: double-buffered halo DMA
    permute: bool = False         # pallas: nnz-balanced bank
    block_m: Optional[int] = None  # bsr: BCSR tile shape
    block_n: Optional[int] = None
    value_dtype: str = "float32"   # pallas/bsr: value-storage dtype
    est_s: float = 0.0
    source: str = "heuristic"     # measured | roofline | heuristic
    # Where this entry came from *this run* — freshly_tuned | cache_hit |
    # migrated | default (see ExecutionReport).  Ephemeral bookkeeping for
    # telemetry: excluded from equality (a reloaded plan must still compare
    # equal to the freshly-tuned one that produced it) and from to_dict()
    # (the on-disk schema is unchanged).
    provenance: str = dataclasses.field(default="freshly_tuned",
                                        compare=False, repr=False)

    @property
    def candidate(self) -> Candidate:
        return Candidate(method=self.method, tm=self.tm, pad_to=self.pad_to,
                         te=self.te, tf=self.tf, fuse=self.fuse,
                         pipeline=self.pipeline, permute=self.permute,
                         block_m=self.block_m, block_n=self.block_n,
                         value_dtype=self.value_dtype)

    def to_dict(self) -> dict:
        return {"method": self.method, "tm": self.tm, "pad_to": self.pad_to,
                "te": self.te, "tf": self.tf, "fuse": self.fuse,
                "pipeline": self.pipeline, "permute": self.permute,
                "block_m": self.block_m, "block_n": self.block_n,
                "value_dtype": self.value_dtype,
                "est_s": self.est_s, "source": self.source}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanEntry":
        # Migration: absent te/tf means the untiled schedule (v1), absent
        # fuse the unfused three-pass epilogue (v1/v2), absent
        # pipeline/permute the blocking single-buffer DMA over a
        # natural-order bank (v1-v3), absent block_m/block_n no BCSR tile
        # shape (v1-v4; executors fall back if such an entry claims
        # method="bsr"), absent value_dtype an f32 value stream (v1-v5) —
        # each the schedule those kernels ran.
        return cls(method=d["method"], tm=d.get("tm"), pad_to=d.get("pad_to"),
                   te=d.get("te"), tf=d.get("tf"),
                   fuse=bool(d.get("fuse", False)),
                   pipeline=bool(d.get("pipeline", False)),
                   permute=bool(d.get("permute", False)),
                   block_m=d.get("block_m"), block_n=d.get("block_n"),
                   value_dtype=d.get("value_dtype", "float32"),
                   est_s=float(d.get("est_s", 0.0)),
                   source=d.get("source", "heuristic"))


def sparsity_bucket(sparsity: float) -> float:
    return round(round(sparsity / SPARSITY_BUCKET) * SPARSITY_BUCKET, 2)


def layer_key(g: ConvGeometry, backend: str) -> str:
    """Cache key: geometry x epilogue x sparsity bucket x dtype x backend.

    The epilogue part (``ep<relu><residual>``) keys the fuse axis: two convs
    with identical geometry but different fused epilogues (e.g. a bottleneck
    tail with a shortcut vs a plain conv+ReLU) must never share an entry —
    their candidate spaces and traffic models differ.
    """
    return (f"m{g.m}_c{g.c}_h{g.h}w{g.w}_r{g.r}s{g.s}_st{g.stride}"
            f"_p{g.pad}_n{g.batch}_ep{int(g.relu)}{int(g.residual)}"
            f"_sp{sparsity_bucket(g.sparsity)}_{g.dtype}_{backend}")


class PlanCache:
    """In-memory plan table with JSON load/save."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, PlanEntry] = {}
        if path and os.path.exists(path):
            self.load(path)

    def get(self, key: str) -> Optional[PlanEntry]:
        return self.entries.get(key)

    def put(self, key: str, entry: PlanEntry) -> None:
        self.entries[key] = entry

    def load(self, path: Optional[str] = None, *,
             strict: bool = False) -> "PlanCache":
        """Load a plan-cache document, resiliently by default.

        A plan cache is an accelerator, not a correctness input, so a
        corrupt, truncated, or unknown-schema file must not take a deploy
        down.  By default every load failure — unreadable file, invalid
        JSON, a non-migratable version, a malformed document shape — emits
        a :class:`PlanCacheWarning` (plus the ``tuning.cache.load_errors``
        counter when telemetry is on) and leaves the cache *empty*, exactly
        as on a cold deploy; individually malformed entries are dropped the
        same way without discarding their healthy siblings.
        ``strict=True`` restores the raising behaviour — what the
        ``repro.analysis`` plan-cache audit uses to localise corruption.
        """
        path = path or self.path
        self.entries = {}
        try:
            with open(path) as fh:
                doc = json.load(fh)
            if not isinstance(doc, dict):
                raise ValueError(
                    f"plan cache {path} is not a JSON object "
                    f"(got {type(doc).__name__})")
            version = doc.get("version")
            if version != CACHE_VERSION and version not in MIGRATABLE_VERSIONS:
                raise ValueError(
                    f"plan cache {path} has version {version!r}, "
                    f"expected {CACHE_VERSION} (or migratable "
                    f"{MIGRATABLE_VERSIONS})")
            raw = doc.get("entries", {})
            if not isinstance(raw, dict):
                raise ValueError(
                    f"plan cache {path} 'entries' is not an object")
        except (OSError, json.JSONDecodeError, UnicodeDecodeError,
                ValueError) as exc:
            if strict:
                raise
            self._load_error(path, str(exc))
            return self
        # v1-v5 migration happens in from_dict: absent te/tf default to None
        # (the untiled schedule), absent fuse to False (the unfused
        # epilogue), absent pipeline/permute to False (blocking DMA,
        # natural row order), absent block_m/block_n to None (no BCSR
        # shape), and absent value_dtype to "float32" (f32 value stream).
        # save() re-persists as the current version.
        provenance = "cache_hit" if version == CACHE_VERSION else "migrated"
        dropped = []
        for k, v in raw.items():
            try:
                entry = PlanEntry.from_dict(v)
            except (TypeError, KeyError, ValueError, AttributeError) as exc:
                if strict:
                    raise ValueError(
                        f"plan cache {path} entry {k!r} is malformed: {exc}"
                    ) from exc
                dropped.append(k)
                continue
            self.entries[k] = dataclasses.replace(entry,
                                                  provenance=provenance)
        if dropped:
            self._load_error(
                path, f"dropped {len(dropped)} malformed entr"
                      f"{'y' if len(dropped) == 1 else 'ies'} "
                      f"(e.g. {dropped[0]!r})")
        from repro import telemetry  # local: keep module deps one-way
        if telemetry.is_enabled():
            telemetry.counter("tuning.cache.loads").inc()
            telemetry.counter("tuning.cache.loaded_entries").inc(
                len(self.entries))
            if version != CACHE_VERSION:
                telemetry.counter("tuning.cache.load_migrations").inc(
                    len(self.entries))
        return self

    @staticmethod
    def _load_error(path: Optional[str], detail: str) -> None:
        """One non-strict load failure: warn + gated telemetry counter."""
        warnings.warn(
            f"plan cache {path}: {detail}; continuing with an empty cache "
            "(the planner will re-tune)", PlanCacheWarning, stacklevel=3)
        from repro import telemetry  # local: keep module deps one-way
        if telemetry.is_enabled():
            telemetry.counter("tuning.cache.load_errors").inc()

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("no cache path given")
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        doc = {"version": CACHE_VERSION,
               "entries": {k: e.to_dict() for k, e in sorted(self.entries.items())}}
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
        os.replace(tmp, path)
        return path

    def __len__(self) -> int:
        return len(self.entries)
