"""Candidate scoring: wall-clock measurement with an analytical fallback.

Two scoring modes, both returning seconds (lower is better):

  ``mode="wall"``     -- jit + warmup + median-of-k wall time (the canonical
                         timer; ``benchmarks/common.py`` re-exports it).  The
                         Pallas kernels (ELL ``pallas`` and BCSR ``bsr``) are
                         only wall-timed on a real TPU backend — in interpret
                         mode their Python-executed time is meaningless, so
                         they are excluded from measurement.
  ``mode="roofline"`` -- analytic max(compute, memory) bound reusing the
                         constants of ``launch/roofline.py``.  Used in CI /
                         interpret mode and whenever measurement is disabled;
                         also how pallas-vs-rest is ranked on CPU.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.direct_conv import dense_conv, direct_sparse_conv
from repro.core.lowering import lowered_sparse_conv
from repro.core.sparse_format import (balance_ell_conv, bcsr_conv_from_dense,
                                      ell_from_dense, ell_from_dense_conv,
                                      quantize_values)
from repro.kernels.bsr_conv.ops import bsr_conv
from repro.kernels.sparse_conv.ops import (apply_epilogue, halo_extent,
                                           sparse_conv)
from repro.launch.roofline import (HBM_BW, PEAK_FLOPS, VPU_FLOPS,
                                   value_itemsize)
from repro.tuning.space import Candidate, ConvGeometry


def _value_stream_bytes(n_values: float, m_rows: int, itemsize: int,
                        value_dtype: str) -> float:
    """HBM bytes of one sparse value stream: the values at their storage
    width plus, for a quantised dtype, the per-output-channel f32 scale
    row.  ``itemsize`` is the bank's native width (the input dtype's) —
    what a ``value_dtype="float32"`` candidate streams; quantised dtypes
    are priced at ``roofline.value_itemsize`` instead.  This is the
    roofline's byte credit for narrow value storage, and the reason every
    int8 bench row reports strictly fewer HBM bytes than its f32 twin
    (scale row < saved value bytes whenever a row has >= 2 values)."""
    if value_dtype == "float32":
        return float(n_values) * itemsize
    return float(n_values) * value_itemsize(value_dtype) + 4.0 * m_rows


class TimingStats(float):
    """Median wall seconds with the (min, max) spread riding along.

    A ``float`` subclass whose value *is* the p50, so every existing
    caller's arithmetic (``t * 1e3``, comparisons, sorting) keeps working
    unchanged, while ``.min`` / ``.max`` expose the measurement spread —
    a wide spread means the median was lucky, not representative.
    """

    __slots__ = ("min", "max")

    def __new__(cls, p50: float, tmin: Optional[float] = None,
                tmax: Optional[float] = None) -> "TimingStats":
        self = super().__new__(cls, p50)
        self.min = float(p50 if tmin is None else tmin)
        self.max = float(p50 if tmax is None else tmax)
        return self

    @property
    def p50(self) -> float:
        return float(self)

    @property
    def spread(self) -> float:
        return self.max - self.min

    def __repr__(self) -> str:
        return (f"TimingStats(p50={float(self):.3e}, min={self.min:.3e}, "
                f"max={self.max:.3e})")


def time_fn(fn: Callable, *args, warmup: int = 2,
            iters: int = 5) -> TimingStats:
    """(min, p50, max) wall time of a jitted call, as a :class:`TimingStats`
    (a float equal to the median, so callers doing arithmetic are
    unaffected; the spread makes noisy measurements visible)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return TimingStats(times[len(times) // 2], times[0], times[-1])


# ---------------------------------------------------------------------------
# analytic roofline scoring
# ---------------------------------------------------------------------------

def epilogue_bytes(g: ConvGeometry, fused: bool) -> float:
    """HBM traffic the conv's epilogue (bias / ReLU / shortcut) costs.

    Unfused, every epilogue stage is a full round-trip of the output tensor:
    the bias add reads and rewrites it (plus the bias row), the ReLU reads
    and rewrites it again, and a bottleneck shortcut reads the output, the
    shortcut tensor, and writes once more.  Fused, the epilogue runs on the
    f32 accumulator in VMEM — only the bias row and (for bottleneck tails)
    one read of the shortcut tensor ever touch HBM.  This is the tuner's
    credit for the saved passes.
    """
    n, m = g.batch, g.m
    dout = float(n * m * g.e * g.f * 4)
    bias = float(m * 4)
    if fused:
        return bias + (dout if g.residual else 0.0)
    extra = 2 * dout + bias                       # bias pass
    if g.relu:
        extra += 2 * dout                         # ReLU pass
    if g.residual:
        extra += 2 * dout + dout                  # add pass + shortcut read
    return extra


def permute_bytes(g: ConvGeometry, permuted: bool) -> float:
    """HBM traffic the nnz-balanced bank's inverse output permutation costs:
    one read + one write of the f32 output tensor (the gather restoring
    natural channel order), plus the permutation row itself."""
    if not permuted:
        return 0.0
    return 2.0 * g.batch * g.m * g.e * g.f * 4 + g.m * 4


def staged_input_bytes(g: ConvGeometry, cand: Candidate) -> float:
    """Input bytes the Pallas kernel stages HBM->VMEM over the whole launch:
    one halo'd block per (image, spatial-tile) grid cell.  Smaller (te, tf)
    tiles re-fetch more halo overlap — the tuner's main spatial signal."""
    e, f = g.e, g.f
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    te = min(cand.te or e, e)
    tf = min(cand.tf or f, f)
    halo_h = halo_extent(te, g.stride, g.r)
    halo_w = halo_extent(tf, g.stride, g.s)
    cells = ((e + te - 1) // te) * ((f + tf - 1) // tf)
    return float(g.batch * cells * g.c * halo_h * halo_w * itemsize)


def _pallas_terms(g: ConvGeometry, cand: Candidate):
    """(compute_s, staged_s, other_mem_s) for one pallas candidate.

    Compute: the kernel's per-row loop is bounded by that row's true nnz
    and the TM-tile's rows execute sequentially on the TPU's single
    sequential grid, so tile compute is the *sum* of row nnz — invariant
    under row permutation — priced at the VPU FMA rate (the per-nonzero
    broadcast-FMA loop issues on the vector unit; the systolic arrays are
    the bsr path's territory, :func:`_bsr_terms`).  The analytic bound is
    therefore the true flop count for balanced and natural-order banks
    alike; ``permute`` only
    shows up on the memory side (the inverse-permutation gather,
    :func:`permute_bytes`).  Any scheduling benefit of near-equal rows per
    unrolled tile (the GPU-side balancing win of Yao et al.,
    arXiv:1811.00206) is below this model's resolution — wall-mode tuning
    is what can detect it.  Other memory: output + ELL + epilogue (+ the
    permute gather's output round-trip).
    """
    n, m = g.batch, g.m
    e, f = g.e, g.f
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    k_pad = g.k_est(cand.pad_to or 8)
    nnz = float(m * g.row_nnz_est)
    fl = 2.0 * n * nnz * e * f
    dout = float(n * m * e * f * 4)
    ell_bytes = (_value_stream_bytes(m * k_pad, m, itemsize, cand.value_dtype)
                 + float(m * k_pad * 4))  # + packed index
    other = (dout + ell_bytes + epilogue_bytes(g, fused=cand.fuse)
             + permute_bytes(g, cand.permute))
    return (fl / VPU_FLOPS, staged_input_bytes(g, cand) / HBM_BW,
            other / HBM_BW)


def bcsr_true_kept(w_dense: np.ndarray, bm: int, bn: int) -> float:
    """Mean kept (any-nonzero) tiles per block-row of the *actual* bank a
    (bm, bn)-blocked ``bcsr_conv_from_dense`` would build from ``w_dense``.

    The geometry-only estimate (``ConvGeometry.bsr_grid``) assumes
    block-structured pruning; on unstructured magnitude-pruned weights
    nearly every tile contains a nonzero, so the real bank is far denser.
    When the planner has the weights in hand it recosts bsr candidates
    with this true count instead of the estimate.
    """
    w = np.asarray(w_dense)
    m = w.shape[0]
    flat = w.reshape(m, -1)
    n2 = flat.shape[1]
    pm, pn = (-m) % bm, (-n2) % bn
    wp = np.pad(flat, ((0, pm), (0, pn)))
    gbm, gbn = wp.shape[0] // bm, wp.shape[1] // bn
    tiles = wp.reshape(gbm, bm, gbn, bn).transpose(0, 2, 1, 3)
    keep = (tiles != 0).any(axis=(2, 3))
    return max(1.0, float(keep.sum(axis=1).mean()))


def _bsr_terms(g: ConvGeometry, cand: Candidate,
               kept_override: Optional[float] = None):
    """(compute_s, staged_s, other_mem_s) for one bsr (BCSR MXU) candidate.

    Compute has two serialized stages per kept weight tile: the *gather*
    (VPU — bn strided windows of te*tf elements copied from the staged halo
    block into the patch tile) and the *contraction* (MXU — one
    (bm, bn) x (bn, te*tf) systolic pass at the dense-unit peak).  Bigger
    bm amortises the gather over more systolic rows; that ratio is the
    tile-gather-vs-systolic-compute tradeoff this model prices against the
    ELL kernel's pure-VPU FMA loop (:func:`_pallas_terms`).  Kept-block
    counts assume block-structured pruning at the layer's sparsity
    (``ConvGeometry.bsr_grid``) unless ``kept_override`` supplies the
    actual bank's mean kept-per-row (:func:`bcsr_true_kept` — what the
    planner passes when it has the layer's weights).  Memory: the same
    halo staging model as the ELL kernel (blocking DMA), plus the kept
    weight tiles, the f32 output, and the epilogue traffic.
    """
    bm, bn = cand.block_m or 8, cand.block_n or 128
    gbm, _, kept = g.bsr_grid(bm, bn)
    if kept_override is not None:
        kept = kept_override
    n = g.batch
    e, f = g.e, g.f
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    te = min(cand.te or e, e)
    tf = min(cand.tf or f, f)
    cells = ((e + te - 1) // te) * ((f + tf - 1) // tf)
    mxu_fl = 2.0 * n * gbm * kept * bm * bn * e * f
    gather_elems = float(n * cells * gbm * kept * bn * te * tf)
    compute_s = mxu_fl / PEAK_FLOPS + gather_elems / VPU_FLOPS
    dout = float(n * gbm * bm * e * f * 4)
    w_bytes = _value_stream_bytes(gbm * kept * bm * bn, gbm * bm, itemsize,
                                  cand.value_dtype)
    other = dout + w_bytes + epilogue_bytes(g, fused=cand.fuse)
    return (compute_s, staged_input_bytes(g, cand) / HBM_BW, other / HBM_BW)


def staging_stall_s(g: ConvGeometry, cand: Candidate) -> float:
    """Seconds the VPU idles waiting on staged-input DMA under this schedule.

    Blocking (``pipeline=False``): every cell's halo copy is a
    ``start(); wait()`` pair — the VPU idles for the entire copy, so the
    full staged-input time is exposed.  Double-buffered
    (``pipeline=True``): each cell's copy flies behind the previous cell's
    FMA work, so the VPU only waits for the part of the copy that outlasts
    compute.  Strictly smaller than the blocking stall whenever there is
    any compute to hide behind (always, for a nonzero filter bank).  Note
    this is a VPU-wait metric, not a total-time delta: the copied bytes
    still cross the shared HBM bus, which :func:`roofline_estimate` keeps
    in the memory term for both schedules.
    """
    terms = (_bsr_terms if cand.method == "bsr" else _pallas_terms)(g, cand)
    t_fl, t_stage, _ = terms
    if not cand.pipeline:
        return t_stage
    return max(0.0, t_stage - t_fl)


def roofline_estimate(g: ConvGeometry, cand: Candidate,
                      w_dense: Optional[np.ndarray] = None,
                      bsr_kept: Optional[float] = None) -> float:
    """max(compute, memory) time bound for one candidate, in seconds.

    ``w_dense`` (optional) supplies the layer's actual pruned weights; it
    only affects bsr candidates, whose kept-block counts are then measured
    from the real bank (:func:`bcsr_true_kept`) instead of assuming
    block-structured pruning at the nominal sparsity.  ``bsr_kept``
    short-circuits that scan with a precomputed mean kept-per-row (the
    planner computes it once per block shape, not once per candidate).

    Mirrors the per-method byte/flop accounting of fig8's TPU projection,
    refined with the execution-unit split: dense conv and the bsr path
    contract on the MXU (``PEAK_FLOPS``), while the per-nonzero FMA loops
    of lowered / csr-direct / pallas issue on the VPU (``VPU_FLOPS``) —
    the crossover that makes block sparsity worthwhile at moderate
    densities, and the reason moderately-sparse large-channel layers used
    to be stuck below the dense roofline:

      dense       streams input + output + dense weights; full dense flops
                  at the MXU peak.
      lowered     materialises the duplicated im2col matrix twice (write +
                  read) — the bandwidth waste the paper's direct method
                  removes; sparse VPU flops over the padded ELL rows.
      csr-direct  streams input + output + ELL (value, packed idx); the scan
                  covers all K padded slots, so padded K costs (VPU) flops.
      bsr         the BCSR MXU path: same halo staging model as pallas
                  (blocking DMA), kept weight tiles streamed, compute =
                  serialized VPU patch gather + MXU tile contractions
                  (:func:`_bsr_terms` — the gather-vs-systolic tradeoff).
      pallas      same traffic, but the halo'd input block is staged
                  HBM->VMEM once per (image, spatial-tile) grid cell and
                  reused across channel tiles: smaller (te, tf) tiles cost
                  more halo re-fetch (the tuner's main spatial signal),
                  while the nnz loop bound skips padding, so padded K costs
                  no flops (see :func:`_pallas_terms` for why the bound is
                  permutation-invariant; an nnz-balanced ``permute`` bank
                  additionally pays the inverse-permutation gather,
                  :func:`permute_bytes`).  The halo DMA schedule decides
                  how staging composes: blocking stages with
                  ``start(); wait()``, so the VPU idles for every copy and
                  the bound is ``staged + max(compute, other-traffic)``;
                  double-buffered (``pipeline``) staging overlaps the
                  copies with compute, recovering the classic
                  ``max(compute, staged + other-traffic)`` — staging and
                  other traffic still *sum* in the memory term (they share
                  the HBM bus; overlap hides latency, it does not
                  manufacture bandwidth).  The recovered VPU idle time is
                  the pipeline's roofline credit (:func:`staging_stall_s`
                  exposes each schedule's stall for the bench tables).

    Every method additionally pays its epilogue traffic
    (:func:`epilogue_bytes`): the unfused bias/ReLU/shortcut passes for
    dense/lowered/csr-direct and unfused pallas, or just the bias row (+ one
    shortcut read) for a fused pallas candidate — the saved output passes
    are the fused epilogue's roofline credit.
    """
    n, m, c = g.batch, g.m, g.c
    rs = g.r * g.s
    e, f = g.e, g.f
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    din = float(n * c * g.hp * g.wp * itemsize)
    dout = float(n * m * e * f * 4)          # f32 accumulate
    dense_fl = 2.0 * n * m * c * rs * e * f
    ep_unfused = epilogue_bytes(g, fused=False)
    if cand.method == "dense":
        return max(dense_fl / PEAK_FLOPS,
                   (din + dout + itemsize * m * c * rs + ep_unfused) / HBM_BW)
    if cand.method == "bsr":
        # Blocking halo DMA (like un-pipelined pallas): the unit stalls for
        # every cell's staged copy, so staging serialises with the rest.
        # With the layer's weights in hand, kept-block counts come from the
        # *actual* bank — unstructured magnitude-pruned weights keep nearly
        # every tile, and pricing them with the block-structured estimate
        # would route such layers to a slower-than-dense schedule.
        kept = bsr_kept
        if kept is None and w_dense is not None:
            kept = bcsr_true_kept(w_dense, cand.block_m or 8,
                                  cand.block_n or 128)
        t_c, t_stage, t_other = _bsr_terms(g, cand, kept_override=kept)
        return t_stage + max(t_c, t_other)
    k_pad = g.k_est(cand.pad_to or 8)
    ell_bytes = float(m * k_pad * (itemsize + 4))  # value + packed index
    padded_fl = 2.0 * n * m * k_pad * e * f
    if cand.method == "lowered":
        im2col = float(n * c * rs * e * f * itemsize)
        return max(padded_fl / VPU_FLOPS,
                   (2 * im2col + dout + ell_bytes + ep_unfused) / HBM_BW)
    if cand.method == "csr-direct":
        return max(padded_fl / VPU_FLOPS,
                   (din + dout + ell_bytes + ep_unfused) / HBM_BW)
    if cand.method == "pallas":
        t_fl, t_stage, t_other = _pallas_terms(g, cand)
        if cand.pipeline:
            # Copies overlap compute; all bytes still share HBM bandwidth.
            return max(t_fl, t_stage + t_other)
        # Blocking start();wait(): the VPU idles for every cell's copy, so
        # staging serialises with the max of compute and other traffic.
        return t_stage + max(t_fl, t_other)
    raise ValueError(cand.method)


def candidate_cost(g: ConvGeometry, cand: Candidate,
                   w_dense: Optional[np.ndarray] = None,
                   bsr_kept: Optional[float] = None) -> dict:
    """Roofline attribution for one candidate: the flop count, total HBM
    bytes, staging-stall seconds, and the :func:`roofline_estimate` bound,
    as one dict — what the engine's ExecutionReport charges each op.

    The flop/byte terms are exactly the ones :func:`roofline_estimate`
    prices (per-method execution-unit split and all); this just returns
    them instead of collapsing to the max.  ``staging_stall_s`` is nonzero
    only for the halo-staging kernels (pallas / bsr).
    """
    n, m, c = g.batch, g.m, g.c
    rs = g.r * g.s
    e, f = g.e, g.f
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    din = float(n * c * g.hp * g.wp * itemsize)
    dout = float(n * m * e * f * 4)
    ep_unfused = epilogue_bytes(g, fused=False)
    est_s = roofline_estimate(g, cand, w_dense=w_dense, bsr_kept=bsr_kept)
    stall = (staging_stall_s(g, cand)
             if cand.method in ("pallas", "bsr") else 0.0)
    if cand.method == "dense":
        flops = 2.0 * n * m * c * rs * e * f
        hbm = din + dout + itemsize * m * c * rs + ep_unfused
    elif cand.method == "bsr":
        bm, bn = cand.block_m or 8, cand.block_n or 128
        gbm, _, kept = g.bsr_grid(bm, bn)
        if bsr_kept is not None:
            kept = bsr_kept
        elif w_dense is not None:
            kept = bcsr_true_kept(w_dense, bm, bn)
        flops = 2.0 * n * gbm * kept * bm * bn * e * f
        hbm = (staged_input_bytes(g, cand) + dout
               + _value_stream_bytes(gbm * kept * bm * bn, gbm * bm,
                                     itemsize, cand.value_dtype)
               + epilogue_bytes(g, fused=cand.fuse))
    elif cand.method == "pallas":
        flops = 2.0 * n * m * g.row_nnz_est * e * f
        k_pad = g.k_est(cand.pad_to or 8)
        hbm = (staged_input_bytes(g, cand) + dout
               + _value_stream_bytes(m * k_pad, m, itemsize, cand.value_dtype)
               + float(m * k_pad * 4)
               + epilogue_bytes(g, fused=cand.fuse)
               + permute_bytes(g, cand.permute))
    elif cand.method in ("lowered", "csr-direct"):
        k_pad = g.k_est(cand.pad_to or 8)
        flops = 2.0 * n * m * k_pad * e * f
        ell_bytes = float(m * k_pad * (itemsize + 4))
        if cand.method == "lowered":
            im2col = float(n * c * rs * e * f * itemsize)
            hbm = 2 * im2col + dout + ell_bytes + ep_unfused
        else:
            hbm = din + dout + ell_bytes + ep_unfused
    else:
        raise ValueError(cand.method)
    return {"flops": float(flops), "hbm_bytes": float(hbm),
            "staging_stall_s": float(stall), "est_s": float(est_s)}


# ---------------------------------------------------------------------------
# wall-clock scoring
# ---------------------------------------------------------------------------

def build_runner(g: ConvGeometry, cand: Candidate, w_dense: np.ndarray,
                 *, interpret: bool = True):
    """(fn, args) executing one candidate on a pruned dense (M, C, R, S) bank.

    Every runner executes the conv *plus its epilogue* (bias, and the
    ReLU/shortcut stages the geometry's fused-epilogue flags name), so
    fused and unfused candidates are wall-timed over the same math: unfused
    runners apply the epilogue as separate ops, a ``fuse=True`` pallas
    runner hands it to the kernel.
    """
    rng = np.random.default_rng(1)
    bias = jnp.zeros((g.m,), jnp.float32)
    res = (jnp.asarray(rng.standard_normal(
        (g.batch, g.m, g.e, g.f)).astype(np.float32))
        if g.residual else None)

    def epilogue(y):
        return apply_epilogue(y, bias, g.relu, res)

    if cand.method == "dense":
        fn = jax.jit(lambda x, w: epilogue(
            dense_conv(x, w, stride=g.stride, padding=g.pad)))
        return fn, (jnp.asarray(w_dense),)
    pad_to = cand.pad_to or 8
    if cand.method == "lowered":
        ell2d = ell_from_dense(w_dense.reshape(g.m, -1), pad_to=pad_to)
        fn = jax.jit(lambda x, e2d=ell2d: epilogue(lowered_sparse_conv(
            x, e2d, r=g.r, s=g.s, stride=g.stride, padding=g.pad)))
        return fn, ()
    if cand.method == "bsr":
        # The BCSR bank is built from the pruned weights *as given* — on
        # unstructured-pruned banks most tiles survive, and that denser
        # reality is exactly what the wall clock should see.
        bcc = bcsr_conv_from_dense(
            w_dense, block=(cand.block_m or 8, cand.block_n or 128))
        if cand.value_dtype != "float32":
            bcc = quantize_values(bcc, cand.value_dtype)
        if cand.fuse:
            return jax.jit(lambda x, b=bcc: bsr_conv(
                x, b, stride=g.stride, padding=g.pad, te=cand.te, tf=cand.tf,
                bias=bias, fuse_relu=g.relu, residual=res,
                interpret=interpret)), ()
        return jax.jit(lambda x, b=bcc: epilogue(bsr_conv(
            x, b, stride=g.stride, padding=g.pad, te=cand.te, tf=cand.tf,
            interpret=interpret))), ()
    ell = ell_from_dense_conv(w_dense, pad_to=pad_to)
    if cand.method == "csr-direct":
        fn = jax.jit(lambda x, e=ell: epilogue(direct_sparse_conv(
            x, e, stride=g.stride, padding=g.pad)))
        return fn, ()
    if cand.method == "pallas":
        # Both variants are wrapped in one outer jit so the unfused
        # epilogue's extra ops compile into the same dispatch as the conv —
        # anything else would bill eager-dispatch overhead to the unfused
        # schedule and bias the fused-vs-unfused comparison.  A permute
        # candidate runs the nnz-balanced bank (the inverse-permutation
        # gather it pays for is inside sparse_conv, so it is timed); the
        # pipeline flag picks the halo DMA schedule.  A quantised candidate
        # runs the int8/fp8 bank the plan would pin (scale row prefetched,
        # in-kernel dequantise — the cast cost is timed).
        if cand.value_dtype != "float32":
            ell = quantize_values(ell, cand.value_dtype)
        if cand.permute:
            ell = balance_ell_conv(ell)
        if cand.fuse:
            return jax.jit(lambda x, e=ell: sparse_conv(
                x, e, stride=g.stride, padding=g.pad, tm=cand.tm,
                te=cand.te, tf=cand.tf, bias=bias, fuse_relu=g.relu,
                residual=res, pipeline=cand.pipeline,
                interpret=interpret)), ()
        return jax.jit(lambda x, e=ell: epilogue(sparse_conv(
            x, e, stride=g.stride, padding=g.pad, tm=cand.tm,
            te=cand.te, tf=cand.tf, pipeline=cand.pipeline,
            interpret=interpret))), ()
    raise ValueError(cand.method)


def measure_candidate(g: ConvGeometry, cand: Candidate, w_dense: np.ndarray,
                      x: jax.Array, *, warmup: int = 1, iters: int = 5,
                      interpret: bool = True) -> TimingStats:
    """Median wall seconds (+ spread) for one candidate on real arrays."""
    runner, extra = build_runner(g, cand, w_dense, interpret=interpret)
    if extra:  # dense path: (x, w)
        return time_fn(runner, x, *extra, warmup=warmup, iters=iters)
    return time_fn(runner, x, warmup=warmup, iters=iters)


def measurable(cand: Candidate, backend: Optional[str] = None) -> bool:
    """Whether wall-timing this candidate is meaningful on this backend.

    Pallas kernels (the ELL ``pallas`` path and the BCSR ``bsr`` path) in
    interpret mode are Python-executed — their wall time says nothing about
    the kernel, so off-TPU they are scored by roofline only.
    """
    backend = backend or jax.default_backend()
    return cand.method not in ("pallas", "bsr") or backend == "tpu"
