"""Candidate scoring: wall-clock measurement with an analytical fallback.

Two scoring modes, both returning seconds (lower is better):

  ``mode="wall"``     -- jit + warmup + median-of-k wall time (the canonical
                         timer; ``benchmarks/common.py`` re-exports it).  The
                         Pallas kernel is only wall-timed on a real TPU
                         backend — in interpret mode its Python-executed time
                         is meaningless, so it is excluded from measurement.
  ``mode="roofline"`` -- analytic max(compute, memory) bound reusing the
                         constants of ``launch/roofline.py``.  Used in CI /
                         interpret mode and whenever measurement is disabled;
                         also how pallas-vs-rest is ranked on CPU.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.direct_conv import dense_conv, direct_sparse_conv
from repro.core.lowering import lowered_sparse_conv
from repro.core.sparse_format import ell_from_dense, ell_from_dense_conv
from repro.kernels.sparse_conv.ops import (apply_epilogue, halo_extent,
                                           sparse_conv)
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.tuning.space import Candidate, ConvGeometry


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (seconds) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


# ---------------------------------------------------------------------------
# analytic roofline scoring
# ---------------------------------------------------------------------------

def epilogue_bytes(g: ConvGeometry, fused: bool) -> float:
    """HBM traffic the conv's epilogue (bias / ReLU / shortcut) costs.

    Unfused, every epilogue stage is a full round-trip of the output tensor:
    the bias add reads and rewrites it (plus the bias row), the ReLU reads
    and rewrites it again, and a bottleneck shortcut reads the output, the
    shortcut tensor, and writes once more.  Fused, the epilogue runs on the
    f32 accumulator in VMEM — only the bias row and (for bottleneck tails)
    one read of the shortcut tensor ever touch HBM.  This is the tuner's
    credit for the saved passes.
    """
    n, m = g.batch, g.m
    dout = float(n * m * g.e * g.f * 4)
    bias = float(m * 4)
    if fused:
        return bias + (dout if g.residual else 0.0)
    extra = 2 * dout + bias                       # bias pass
    if g.relu:
        extra += 2 * dout                         # ReLU pass
    if g.residual:
        extra += 2 * dout + dout                  # add pass + shortcut read
    return extra


def roofline_estimate(g: ConvGeometry, cand: Candidate) -> float:
    """max(compute, memory) time bound for one candidate, in seconds.

    Mirrors the per-method byte/flop accounting of fig8's TPU projection:

      dense       streams input + output + dense weights; full dense flops.
      lowered     materialises the duplicated im2col matrix twice (write +
                  read) — the bandwidth waste the paper's direct method
                  removes; sparse flops over the padded ELL rows.
      csr-direct  streams input + output + ELL (value, packed idx); the scan
                  covers all K padded slots, so padded K costs flops.
      pallas      same traffic, but the halo'd input block is staged
                  HBM->VMEM once per (image, spatial-tile) grid cell and
                  reused across channel tiles: smaller (te, tf) tiles cost
                  more halo re-fetch (the tuner's main spatial signal),
                  while the nnz loop bound skips padding, so padded K costs
                  no flops.

    Every method additionally pays its epilogue traffic
    (:func:`epilogue_bytes`): the unfused bias/ReLU/shortcut passes for
    dense/lowered/csr-direct and unfused pallas, or just the bias row (+ one
    shortcut read) for a fused pallas candidate — the saved output passes
    are the fused epilogue's roofline credit.
    """
    n, m, c = g.batch, g.m, g.c
    rs = g.r * g.s
    e, f = g.e, g.f
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    din = float(n * c * g.hp * g.wp * itemsize)
    dout = float(n * m * e * f * 4)          # f32 accumulate
    dense_fl = 2.0 * n * m * c * rs * e * f
    nnz = float(m * g.row_nnz_est)           # true nonzeros (est.)
    ep_unfused = epilogue_bytes(g, fused=False)
    if cand.method == "dense":
        return max(dense_fl / PEAK_FLOPS,
                   (din + dout + itemsize * m * c * rs + ep_unfused) / HBM_BW)
    k_pad = g.k_est(cand.pad_to or 8)
    ell_bytes = float(m * k_pad * (itemsize + 4))  # value + packed index
    padded_fl = 2.0 * n * m * k_pad * e * f
    true_fl = 2.0 * n * nnz * e * f
    if cand.method == "lowered":
        im2col = float(n * c * rs * e * f * itemsize)
        return max(padded_fl / PEAK_FLOPS,
                   (2 * im2col + dout + ell_bytes + ep_unfused) / HBM_BW)
    if cand.method == "csr-direct":
        return max(padded_fl / PEAK_FLOPS,
                   (din + dout + ell_bytes + ep_unfused) / HBM_BW)
    if cand.method == "pallas":
        te = min(cand.te or e, e)
        tf = min(cand.tf or f, f)
        halo_h = halo_extent(te, g.stride, g.r)
        halo_w = halo_extent(tf, g.stride, g.s)
        cells = ((e + te - 1) // te) * ((f + tf - 1) // tf)
        din_staged = float(n * cells * c * halo_h * halo_w * itemsize)
        ep = epilogue_bytes(g, fused=cand.fuse)
        return max(true_fl / PEAK_FLOPS,
                   (din_staged + dout + ell_bytes + ep) / HBM_BW)
    raise ValueError(cand.method)


# ---------------------------------------------------------------------------
# wall-clock scoring
# ---------------------------------------------------------------------------

def build_runner(g: ConvGeometry, cand: Candidate, w_dense: np.ndarray,
                 *, interpret: bool = True):
    """(fn, args) executing one candidate on a pruned dense (M, C, R, S) bank.

    Every runner executes the conv *plus its epilogue* (bias, and the
    ReLU/shortcut stages the geometry's fused-epilogue flags name), so
    fused and unfused candidates are wall-timed over the same math: unfused
    runners apply the epilogue as separate ops, a ``fuse=True`` pallas
    runner hands it to the kernel.
    """
    rng = np.random.default_rng(1)
    bias = jnp.zeros((g.m,), jnp.float32)
    res = (jnp.asarray(rng.standard_normal(
        (g.batch, g.m, g.e, g.f)).astype(np.float32))
        if g.residual else None)

    def epilogue(y):
        return apply_epilogue(y, bias, g.relu, res)

    if cand.method == "dense":
        fn = jax.jit(lambda x, w: epilogue(
            dense_conv(x, w, stride=g.stride, padding=g.pad)))
        return fn, (jnp.asarray(w_dense),)
    pad_to = cand.pad_to or 8
    if cand.method == "lowered":
        ell2d = ell_from_dense(w_dense.reshape(g.m, -1), pad_to=pad_to)
        fn = jax.jit(lambda x, e2d=ell2d: epilogue(lowered_sparse_conv(
            x, e2d, r=g.r, s=g.s, stride=g.stride, padding=g.pad)))
        return fn, ()
    ell = ell_from_dense_conv(w_dense, pad_to=pad_to)
    if cand.method == "csr-direct":
        fn = jax.jit(lambda x, e=ell: epilogue(direct_sparse_conv(
            x, e, stride=g.stride, padding=g.pad)))
        return fn, ()
    if cand.method == "pallas":
        # Both variants are wrapped in one outer jit so the unfused
        # epilogue's extra ops compile into the same dispatch as the conv —
        # anything else would bill eager-dispatch overhead to the unfused
        # schedule and bias the fused-vs-unfused comparison.
        if cand.fuse:
            return jax.jit(lambda x, e=ell: sparse_conv(
                x, e, stride=g.stride, padding=g.pad, tm=cand.tm,
                te=cand.te, tf=cand.tf, bias=bias, fuse_relu=g.relu,
                residual=res, interpret=interpret)), ()
        return jax.jit(lambda x, e=ell: epilogue(sparse_conv(
            x, e, stride=g.stride, padding=g.pad, tm=cand.tm,
            te=cand.te, tf=cand.tf, interpret=interpret))), ()
    raise ValueError(cand.method)


def measure_candidate(g: ConvGeometry, cand: Candidate, w_dense: np.ndarray,
                      x: jax.Array, *, warmup: int = 1, iters: int = 5,
                      interpret: bool = True) -> float:
    """Median wall seconds for one candidate on real arrays."""
    runner, extra = build_runner(g, cand, w_dense, interpret=interpret)
    if extra:  # dense path: (x, w)
        return time_fn(runner, x, *extra, warmup=warmup, iters=iters)
    return time_fn(runner, x, warmup=warmup, iters=iters)


def measurable(cand: Candidate, backend: Optional[str] = None) -> bool:
    """Whether wall-timing this candidate is meaningful on this backend.

    Pallas in interpret mode is Python-executed — its wall time says nothing
    about the kernel, so off-TPU it is scored by roofline only.
    """
    backend = backend or jax.default_backend()
    return cand.method != "pallas" or backend == "tpu"
