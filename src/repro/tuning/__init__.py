"""Kernel-customization autotuner (paper §3.3-3.4 as a subsystem).

Per-layer method/tile selection, measurement-driven with an analytical
roofline fallback, persisted to a JSON plan cache:

  space    -- candidate enumeration (method x (tm, te, tf) x pad_to) from
              geometry; spatial tiles come from the kernel's halo'd-block
              VMEM feasibility model
  measure  -- wall-clock timing + roofline scoring of candidates
  cache    -- versioned JSON plan cache keyed on geometry/sparsity/dtype/backend
  planner  -- network walker producing executable {layer: PlanEntry} plans
"""
from repro.tuning.cache import PlanCache, PlanEntry, layer_key, sparsity_bucket
from repro.tuning.measure import (measurable, measure_candidate,
                                  roofline_estimate, time_fn)
from repro.tuning.planner import (apply_plan_to_params, format_plan,
                                  geometry_for, plan_layer, plan_network)
from repro.tuning.space import (Candidate, ConvGeometry, enumerate_candidates,
                                METHODS, PAD_TO_BUCKETS, pallas_feasible)

__all__ = [
    "Candidate", "ConvGeometry", "METHODS", "PAD_TO_BUCKETS", "PlanCache",
    "PlanEntry", "apply_plan_to_params", "enumerate_candidates", "format_plan",
    "geometry_for", "layer_key", "measurable", "measure_candidate",
    "pallas_feasible", "plan_layer", "plan_network", "roofline_estimate",
    "sparsity_bucket", "time_fn",
]
