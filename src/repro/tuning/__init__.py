"""Kernel-customization autotuner (paper §3.3-3.4 as a subsystem).

Per-layer method/tile selection, measurement-driven with an analytical
roofline fallback, persisted to a JSON plan cache:

  space    -- candidate enumeration (method x (tm, te, tf) x pad_to x fuse
              x pipeline x permute x BCSR (block_m, block_n)) from
              geometry; spatial tiles come from the kernels' halo'd-block
              VMEM feasibility models (pipelined tilings reserve the
              second halo buffer), the fuse axis from the conv's lowered
              epilogue (bias/ReLU/shortcut in-kernel)
  measure  -- wall-clock timing + roofline scoring of candidates (the
              roofline credits the fused epilogue's saved output passes,
              the pipelined schedule's overlapped staging bytes, and the
              balanced bank's equalised channel tiles, and prices the MXU
              systolic peak against the VPU FMA rate — the crossover that
              sends moderately-sparse layers to the BCSR ``bsr`` method)
  cache    -- versioned JSON plan cache keyed on geometry/epilogue/sparsity/
              dtype/backend
  planner  -- plans the engine's lowered program (one ConvOp at a time)
              into executable {layer: PlanEntry} tables
"""
from repro.tuning.cache import PlanCache, PlanEntry, layer_key, sparsity_bucket
from repro.tuning.measure import (epilogue_bytes, measurable,
                                  measure_candidate, permute_bytes,
                                  roofline_estimate, staged_input_bytes,
                                  staging_stall_s, time_fn)
from repro.tuning.planner import (apply_plan_to_params, format_plan,
                                  geometry_for, geometry_of_op, plan_layer,
                                  plan_network, plan_program)
from repro.tuning.space import (Candidate, ConvGeometry, bsr_feasible,
                                enumerate_candidates, METHODS,
                                PAD_TO_BUCKETS, pallas_feasible)

__all__ = [
    "Candidate", "ConvGeometry", "METHODS", "PAD_TO_BUCKETS", "PlanCache",
    "PlanEntry", "apply_plan_to_params", "bsr_feasible",
    "enumerate_candidates",
    "epilogue_bytes", "format_plan", "geometry_for", "geometry_of_op",
    "layer_key", "measurable", "measure_candidate", "pallas_feasible",
    "permute_bytes", "plan_layer", "plan_network", "plan_program",
    "roofline_estimate", "sparsity_bucket", "staged_input_bytes",
    "staging_stall_s", "time_fn",
]
