"""Network-level planner: plan a lowered engine program, per-conv-op.

The planner turns the static candidate space (``space.py``) plus a scoring
mode (``measure.py``) into a ``{layer_name: PlanEntry}`` plan, consulting and
filling a persistent :class:`~repro.tuning.cache.PlanCache` so tuning runs
once per deployment.  It operates on the engine's flat lowered program
(``repro.engine.lower``) — the spec is walked exactly once, by the engine,
and the planner iterates the resulting ``ConvOp`` list with every geometry
(including the fused-epilogue flags) already resolved.

Identical geometries (e.g. repeated ResNet bottlenecks) share one key and
are scored once per run even without a persistent cache; the key includes
the epilogue signature, so a bottleneck-tail conv (fused shortcut) never
reuses the measurement of a plain conv+ReLU with the same shape.
``models/cnn.py`` / ``CnnEngine`` execute the plan via ``method="auto"``.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.sparse_format import (bcsr_conv_from_dense, ell_from_dense,
                                      ell_from_dense_conv, quantize_values)
from repro.engine import ConvOp, Program, lower
from repro.tuning.cache import PlanCache, PlanEntry, layer_key
from repro.tuning.measure import (bcsr_true_kept, measurable,
                                  measure_candidate, roofline_estimate)
from repro.tuning.space import (ConvGeometry, allowed_value_dtypes,
                                enumerate_candidates)

_LOG = logging.getLogger("repro.tuning")


def geometry_for(layer: "spec.Conv", c: int, h: int, w: int, *, batch: int = 1,
                 dtype: str = "float32", relu: bool = False,
                 residual: bool = False) -> ConvGeometry:
    """Geometry from a raw layer spec (no epilogue flags unless given)."""
    return ConvGeometry(
        name=layer.name, m=layer.out_c, c=c, h=h, w=w, r=layer.k, s=layer.k,
        stride=layer.stride, pad=layer.pad, sparsity=layer.sparsity,
        batch=batch, dtype=dtype, relu=relu, residual=residual)


def geometry_of_op(op: ConvOp, *, batch: int = 1,
                   dtype: str = "float32") -> ConvGeometry:
    """Geometry from a lowered ``ConvOp`` — carries the fused-epilogue
    signature (ReLU / bottleneck shortcut) into the cache key and the
    candidate space's ``fuse`` axis."""
    return ConvGeometry(
        name=op.name, m=op.m, c=op.c, h=op.h, w=op.w, r=op.k, s=op.k,
        stride=op.stride, pad=op.pad, sparsity=op.sparsity, batch=batch,
        dtype=dtype, relu=op.fuse_relu, residual=op.res is not None)


def plan_layer(g: ConvGeometry, *, mode: str = "roofline",
               w_dense: Optional[np.ndarray] = None, backend: str = "cpu",
               interpret: Optional[bool] = None, warmup: int = 1,
               iters: int = 3, quantize: bool = False) -> PlanEntry:
    """Score every valid candidate for one layer and return the winner.

    ``interpret=None`` resolves per backend: compiled on TPU, interpret
    elsewhere — wall-timing an interpret-mode Pallas kernel would measure
    the Python interpreter, not the kernel.  ``w_dense`` is required for
    wall mode and *used* by roofline mode when given: bsr candidates are
    then priced from the actual bank's kept-block structure instead of
    the block-structured-pruning estimate (unstructured magnitude-pruned
    weights keep nearly every tile — the estimate would send such layers
    to a slower-than-dense MXU schedule).

    ``quantize=True`` opts the candidate space into the narrow
    value-storage dtypes (int8, and fp8 on TPU backends).  It is opt-in
    because narrow storage is *lossy* — on memory-bound sparse layers the
    roofline all but always prefers the smaller value stream, so a default
    planner run would silently trade accuracy for bandwidth; a plan that
    pins a narrow dtype is an explicit artifact instead.
    """
    if interpret is None:
        interpret = backend != "tpu"
    # The value-dtype axis is backend-capability-filtered up front: a plan
    # must never pin a dtype the backend cannot execute (fp8 off-TPU) —
    # the static verifier flags any such entry as a pre-flight error.
    cands = enumerate_candidates(
        g, value_dtypes=(allowed_value_dtypes(backend) if quantize
                         else ("float32",)))
    if mode == "wall":
        cands = [cd for cd in cands if measurable(cd, backend)]
    if not cands:
        return PlanEntry(method="dense", source="heuristic",
                         provenance="default")
    best, best_t = None, float("inf")
    rng = np.random.default_rng(0)
    x = None
    if mode == "wall":
        if w_dense is None:
            raise ValueError("wall-mode tuning needs the layer's dense weights")
        x = jnp.asarray(rng.standard_normal(
            (g.batch, g.c, g.h, g.w)).astype(np.float32))
    kept_by_block: Dict[Any, float] = {}
    for cd in cands:
        if mode == "wall":
            t = measure_candidate(g, cd, w_dense, x, warmup=warmup,
                                  iters=iters, interpret=interpret)
            # time_fn returns TimingStats: surface the (min, p50, max)
            # spread so a lucky median is visible in the tuning log.
            _LOG.debug(
                "wall %s %s: p50=%.1fus min=%.1fus max=%.1fus", g.name,
                cd, t * 1e6, t.min * 1e6, t.max * 1e6)
        elif cd.method == "bsr" and w_dense is not None:
            # One bank scan per block shape, not per candidate — the
            # ladder has ~4 shapes but ~dozens of (te, tf, fuse) points.
            blk = (cd.block_m or 8, cd.block_n or 128)
            if blk not in kept_by_block:
                kept_by_block[blk] = bcsr_true_kept(w_dense, *blk)
            t = roofline_estimate(g, cd, bsr_kept=kept_by_block[blk])
        else:
            t = roofline_estimate(g, cd)
        if t < best_t:
            best, best_t = cd, t
    if mode == "wall":
        _LOG.info(
            "wall winner %s %s: p50=%.1fus spread=[%.1fus, %.1fus]",
            g.name, best.method, best_t * 1e6,
            getattr(best_t, "min", best_t) * 1e6,
            getattr(best_t, "max", best_t) * 1e6)
    return PlanEntry(method=best.method, tm=best.tm, pad_to=best.pad_to,
                     te=best.te, tf=best.tf, fuse=best.fuse,
                     pipeline=best.pipeline, permute=best.permute,
                     block_m=best.block_m, block_n=best.block_n,
                     value_dtype=best.value_dtype,
                     est_s=best_t,
                     source="measured" if mode == "wall" else "roofline")


def weight_structure_tag(w_dense: np.ndarray) -> str:
    """Cache-key component for weights-aware plans: the bank's kept-tile
    fraction at the default (8, 128) block, bucketed to 10%.

    Weights-aware roofline scores depend on the bank's *block structure*
    (a magnitude-pruned and a block-pruned bank of identical geometry and
    sparsity price bsr very differently), so plans scored with weights in
    hand must not share a cache entry across structures — without this
    tag, a block-pruned model's ``bsr`` plan could be inherited by an
    unstructured bank of the same shape, the exact mis-routing the
    weights-aware costing exists to prevent.
    """
    w = np.asarray(w_dense)
    gbn = max(1, -(-(int(np.prod(w.shape[1:]))) // 128))
    frac = bcsr_true_kept(w, 8, 128) / gbn
    return f"bk{min(1.0, round(frac, 1))}"


def plan_program(program: Program, *, batch: int = 1,
                 dtype: str = "float32", mode: str = "roofline",
                 cache: Optional[PlanCache] = None,
                 params: Optional[Dict[str, Any]] = None,
                 backend: Optional[str] = None,
                 interpret: Optional[bool] = None,
                 warmup: int = 1, iters: int = 3,
                 quantize: bool = False,
                 ) -> Dict[str, PlanEntry]:
    """Tune every conv op of a lowered program; returns name -> PlanEntry.

    Cache hits skip scoring entirely; misses are scored and written back (and
    persisted to ``cache.path`` if set).  Duplicate geometries — same layer
    key, which includes the fused-epilogue signature — are scored once per
    run even with no cache supplied.  ``mode="roofline"`` needs no weights
    but *uses* ``params`` when supplied (bsr candidates are priced from
    each layer's actual kept-block structure); ``mode="wall"`` requires
    them and measures on the pruned weights (as built by ``cnn.init_cnn``
    / ``engine.init_conv_params``).  ``quantize=True`` opts scoring into
    the narrow value-storage dtypes (see :func:`plan_layer`) — quantised
    winners are a deliberate accuracy/bandwidth trade, never a default.
    """
    if mode not in ("roofline", "wall"):
        raise ValueError(f"unknown tuning mode {mode!r}")
    backend = backend or jax.default_backend()
    plan: Dict[str, PlanEntry] = {}
    scored: Dict[str, PlanEntry] = {}
    misses = 0
    for op in program.conv_ops:
        g = geometry_of_op(op, batch=batch, dtype=dtype)
        w_dense = None
        if op.sparsity > 0 and params is not None and op.name in params:
            w_dense = np.asarray(params[op.name]["w"])
        base_key = key = layer_key(g, backend)
        if w_dense is not None:
            # Weights-aware scores depend on the bank's block structure,
            # which the geometry key cannot see: extend the key so e.g. a
            # block-pruned model's bsr plan is never inherited by an
            # unstructured bank of identical geometry.
            key += "_" + weight_structure_tag(w_dense)
        telem = telemetry.is_enabled()
        entry = cache.get(key) if cache is not None else None
        if entry is not None and telem:
            # Entries arrive from load() already marked cache_hit/migrated.
            telemetry.counter(f"tuning.plan.{entry.provenance}").inc()
        if entry is None and cache is not None and key != base_key:
            # Legacy compatibility: pre-tag caches (v1-v4 migrations, or
            # weight-free v5 runs) keyed without the structure tag.  Only
            # bsr pricing is structure-sensitive, so a non-bsr legacy
            # winner is safe to inherit; a legacy bsr entry is not — it
            # may have been priced for a different bank structure.
            legacy = cache.get(base_key)
            if legacy is not None and legacy.method != "bsr":
                entry = dataclasses.replace(legacy, provenance="migrated")
                if telem:
                    telemetry.counter("tuning.plan.legacy_inherit").inc()
            elif legacy is not None and telem:
                # A legacy bsr winner exists but cannot be trusted for this
                # bank structure — the layer re-scores below.
                telemetry.counter("tuning.plan.bsr_structure_rescore").inc()
        if entry is None:
            entry = scored.get(key)
            if entry is not None and telem:
                telemetry.counter("tuning.plan.dedup_hit").inc()
        if entry is None:
            if op.sparsity <= 0:
                # Dense-kept layer: one candidate, nothing to measure.
                entry = PlanEntry(method="dense", source="heuristic",
                                  provenance="default")
            else:
                if mode == "wall" and w_dense is None:
                    raise ValueError(
                        f"wall-mode tuning needs params for {op.name}")
                entry = plan_layer(g, mode=mode, w_dense=w_dense,
                                   backend=backend, interpret=interpret,
                                   warmup=warmup, iters=iters,
                                   quantize=quantize)
            misses += 1
            scored[key] = entry
            if telem:
                telemetry.counter("tuning.plan.scored").inc()
            if cache is not None:
                cache.put(key, entry)
        plan[op.name] = entry
    if cache is not None and cache.path and misses:
        cache.save()
    return plan


def plan_network(net: Sequence[Any], in_c: int, image: int, *, batch: int = 1,
                 **kw) -> Dict[str, PlanEntry]:
    """Convenience wrapper: lower the spec once, then :func:`plan_program`."""
    program = lower(net, (in_c, image, image))
    return plan_program(program, batch=batch, **kw)


def apply_plan_to_params(params: Dict[str, Any],
                         plan: Dict[str, PlanEntry]) -> Dict[str, Any]:
    """Rebuild per-layer sparse formats at each plan's tuned knobs.

    Stores them under ``ell_auto`` / ``ell2d_auto`` / ``bcsr_auto`` next to
    the defaults, so non-auto methods keep working unchanged.  A pallas
    entry with ``permute=True`` gets its bank nnz-balanced here, host-side,
    so the engine never sorts inside a trace; a ``bsr`` entry gets its
    BCSR bank blocked at the plan's (block_m, block_n) — an entry with no
    block shape (a stale pre-v5 plan) is skipped, and the engine falls
    back to dense for it.  A plan pinning a narrow ``value_dtype`` gets
    its bank quantised here, host-side (per-output-channel symmetric
    scales, values stored int8/fp8), so the engine's traced forward only
    ever streams the narrow bank.  Safe to call repeatedly.
    """
    for name, pe in plan.items():
        entry = params.get(name)
        if entry is None or "ell" not in entry:
            continue  # dense-kept layer: nothing to rebuild
        pad_to = pe.pad_to or 8
        w = np.asarray(entry["w"])
        if pe.method == "lowered":
            entry["ell2d_auto"] = ell_from_dense(
                w.reshape(w.shape[0], -1), pad_to=pad_to)
        elif pe.method in ("csr-direct", "pallas"):
            bank = ell_from_dense_conv(
                w, pad_to=pad_to,
                balance=pe.method == "pallas" and pe.permute)
            if pe.method == "pallas" and pe.value_dtype != "float32":
                bank = quantize_values(bank, pe.value_dtype)
            entry["ell_auto"] = bank
        elif (pe.method == "bsr" and pe.block_m is not None
              and pe.block_n is not None):
            bank = bcsr_conv_from_dense(w, block=(pe.block_m, pe.block_n))
            if pe.value_dtype != "float32":
                bank = quantize_values(bank, pe.value_dtype)
            entry["bcsr_auto"] = bank
    return params


def format_plan(plan: Dict[str, PlanEntry]) -> str:
    """Human-readable per-layer plan table (the paper's customization table)."""
    lines = [f"{'layer':<22} {'method':<11} {'tm':>4} {'te':>4} {'tf':>4} "
             f"{'pad_to':>6} {'block':>8} {'fuse':>5} {'pipe':>5} {'perm':>5} "
             f"{'vdtype':>8} {'est_us':>10} source"]
    for name, pe in plan.items():
        block = (f"{pe.block_m}x{pe.block_n}"
                 if pe.block_m and pe.block_n else "-")
        vdt = {"float32": "f32", "float8_e4m3fn": "fp8"}.get(
            pe.value_dtype, pe.value_dtype)
        lines.append(
            f"{name:<22} {pe.method:<11} {pe.tm or '-':>4} "
            f"{pe.te or '-':>4} {pe.tf or '-':>4} "
            f"{pe.pad_to or '-':>6} {block:>8} {'y' if pe.fuse else '-':>5} "
            f"{'y' if pe.pipeline else '-':>5} "
            f"{'y' if pe.permute else '-':>5} "
            f"{vdt:>8} "
            f"{pe.est_s * 1e6:>10.1f} {pe.source}")
    return "\n".join(lines)
