"""Candidate space for per-layer kernel customization (paper §3.3-3.4).

Escoin's speedups come from picking, per conv layer, the execution strategy
and tile shape that fit that layer's geometry and sparsity.  This module
enumerates the discrete choices the tuner measures over:

  method  ∈ {dense, lowered, csr-direct, pallas}   (paper Figs. 8-11 columns)
  tm      ∈ output-channel tiles that divide M and fit VMEM (pallas only)
  pad_to  ∈ ELL row-padding buckets (K granularity; trades padded work for
            jit-specialisation sharing)

Hardware-infeasible points are pruned statically: the Pallas kernel requires
stride == 1 and its packed index array must fit the SMEM budget; fully-dense
layers (sparsity == 0) only ever run dense.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.kernels.sparse_conv.ops import SMEM_BUDGET, tm_candidates

METHODS = ("dense", "lowered", "csr-direct", "pallas")

# ELL K-padding buckets (the paper's kernel-customization table keys on K
# granularity).  8 is the repo-wide default; 4 trims padded work on very
# sparse rows; 16 shares jit specialisations across near-equal layers.
PAD_TO_BUCKETS = (4, 8, 16)


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Static description of one conv layer instance (what the cache keys on).

    m/c: out/in channels; h/w: input spatial dims; r/s: filter dims.
    """

    name: str
    m: int
    c: int
    h: int
    w: int
    r: int
    s: int
    stride: int = 1
    pad: int = 0
    sparsity: float = 0.0
    batch: int = 1
    dtype: str = "float32"

    @property
    def hp(self) -> int:
        return self.h + 2 * self.pad

    @property
    def wp(self) -> int:
        return self.w + 2 * self.pad

    @property
    def e(self) -> int:
        return (self.hp - self.r) // self.stride + 1

    @property
    def f(self) -> int:
        return (self.wp - self.s) // self.stride + 1

    @property
    def row_nnz_est(self) -> int:
        """Expected nonzeros per output channel at this sparsity."""
        return max(1, math.ceil(self.c * self.r * self.s * (1.0 - self.sparsity)))

    def k_est(self, pad_to: int) -> int:
        """Estimated padded ELL row length K for a given pad_to bucket."""
        pad_to = max(1, pad_to)
        k = self.row_nnz_est
        return max(pad_to, ((k + pad_to - 1) // pad_to) * pad_to)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the customization space.

    tm is only meaningful for the pallas method; pad_to only for the sparse
    formats (lowered / csr-direct / pallas).
    """

    method: str
    tm: Optional[int] = None
    pad_to: Optional[int] = None

    def to_dict(self) -> dict:
        return {"method": self.method, "tm": self.tm, "pad_to": self.pad_to}

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        return cls(method=d["method"], tm=d.get("tm"), pad_to=d.get("pad_to"))


def pallas_feasible(g: ConvGeometry, k: int) -> bool:
    """The Pallas kernel is specialised for stride 1 and SMEM-resident indices."""
    return g.stride == 1 and g.m * k * 4 <= SMEM_BUDGET


def enumerate_candidates(g: ConvGeometry,
                         methods: Tuple[str, ...] = METHODS) -> List[Candidate]:
    """All statically-valid customization points for one layer.

    Every emitted pallas ``tm`` divides M and fits the VMEM budget (via
    ``kernels.sparse_conv.ops.tm_candidates`` — the heuristic the tuner
    refines); every pallas candidate fits the SMEM budget.
    """
    if g.sparsity <= 0.0:
        # Dense-kept layers (paper: conv1 et al.) have no sparse format.
        return [Candidate("dense")]
    out: List[Candidate] = []
    if "dense" in methods:
        out.append(Candidate("dense"))
    for pad_to in PAD_TO_BUCKETS:
        k = g.k_est(pad_to)
        if "lowered" in methods:
            out.append(Candidate("lowered", pad_to=pad_to))
        if "csr-direct" in methods:
            out.append(Candidate("csr-direct", pad_to=pad_to))
        if "pallas" in methods and pallas_feasible(g, k):
            for tm in tm_candidates(g.m, g.c, g.hp, g.wp, g.e, g.f, k):
                out.append(Candidate("pallas", tm=tm, pad_to=pad_to))
    return out
