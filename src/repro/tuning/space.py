"""Candidate space for per-layer kernel customization (paper §3.3-3.4).

Escoin's speedups come from picking, per conv layer, the execution strategy
and tile shape that fit that layer's geometry and sparsity.  This module
enumerates the discrete choices the tuner measures over:

  method      ∈ {dense, lowered, csr-direct, pallas, bsr}  (paper Figs. 8-11
               columns, plus the beyond-paper BCSR MXU conv path)
  (tm,te,tf)  ∈ output-channel x output-spatial tilings whose halo'd input
               block + value block + out tile fit the VMEM budget (pallas
               only; te/tf = None means the untiled full-extent schedule)
  pad_to      ∈ ELL row-padding buckets (K granularity; trades padded work
               for jit-specialisation sharing)
  fuse        ∈ {False, True}  (pallas only): execute the conv's epilogue —
               bias add, ReLU, bottleneck shortcut — in-kernel on the f32
               accumulator (one output write) instead of as separate HBM
               passes.  Fused-residual candidates must additionally fit the
               shortcut input tile in VMEM.
  pipeline    ∈ {False, True}  (pallas only): double-buffer the halo DMA —
               stage spatial cell i+1's input block while cell i computes —
               at the cost of a second halo scratch block in VMEM.
               Pipelined candidates enumerate only tilings whose *doubled*
               halo block fits the budget.
  permute     ∈ {False, True}  (pallas only): run an nnz-balanced bank
               (output channels sorted by row nnz) so every TM-tile holds
               rows of near-equal length; costs an inverse-permutation
               gather of the output.
  (bm, bn)    ∈ BCSR block-shape candidates (bsr only): the tile
               granularity of the block-pruned weight matrix.  Bigger bm
               amortises the per-block patch gather over more systolic
               rows; smaller bm wastes less channel padding.  bsr
               candidates also carry (te, tf) spatial tiles and the fuse
               axis, but no tm/pad_to/pipeline/permute — the block shape
               plays tm's role and the kernel's halo DMA is blocking.

Hardware-infeasible points are pruned statically: the Pallas kernel's packed
index array (+ the int32 nnz row + the f32 bias row) must fit the SMEM
budget, and every emitted tiling fits VMEM
(``kernels.sparse_conv.ops.tile_candidates`` /
``kernels.bsr_conv.ops.bsr_tile_candidates``).  Strided layers are eligible
— the kernels apply the stride in-kernel.  Fully-dense layers (sparsity ==
0) only ever run dense.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.kernels.bsr_conv.ops import BLOCK_CANDIDATES, bsr_tile_candidates
from repro.kernels.budget import bsr_smem_fits, smem_fits, value_itemsize
from repro.kernels.sparse_conv.ops import tile_candidates

METHODS = ("dense", "lowered", "csr-direct", "pallas", "bsr")

# Value-storage dtypes the kernel candidates enumerate: f32 banks plus the
# quantised (per-output-channel symmetric scale, f32 accumulate) narrow
# formats.  Only the Pallas paths (pallas / bsr) execute narrow banks —
# dense / lowered / csr-direct candidates stay float32.  Callers (the
# planner) filter this by backend capability: fp8 requires a TPU backend.
VALUE_DTYPES = ("float32", "int8", "float8_e4m3fn")


def allowed_value_dtypes(backend: str) -> Tuple[str, ...]:
    """The value-storage dtypes executable on ``backend`` — the single
    capability policy the planner (candidate filtering) and the static
    verifier (pre-flight plan audits) share.  fp8 (``float8_e4m3fn``)
    needs TPU hardware casts; int8 and f32 run everywhere the Pallas
    paths do (including interpret mode)."""
    if backend == "tpu":
        return VALUE_DTYPES
    return tuple(d for d in VALUE_DTYPES if d != "float8_e4m3fn")

# ELL K-padding buckets (the paper's kernel-customization table keys on K
# granularity).  8 is the repo-wide default; 4 trims padded work on very
# sparse rows; 16 shares jit specialisations across near-equal layers.
PAD_TO_BUCKETS = (4, 8, 16)

# Cap on pallas tilings enumerated per (layer, pad_to, fuse): tile_candidates
# is preference-sorted, so the head of the list is the schedules worth
# measuring.
MAX_TILINGS = 24


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Static description of one conv layer instance (what the cache keys on).

    m/c: out/in channels; h/w: input spatial dims; r/s: filter dims.
    ``relu``/``residual`` describe the epilogue the engine fused into this
    conv at lowering time — they shape the candidate space (the ``fuse``
    axis) and the roofline's epilogue-traffic accounting, so fused and
    unfused variants of an otherwise identical geometry never share a plan.
    """

    name: str
    m: int
    c: int
    h: int
    w: int
    r: int
    s: int
    stride: int = 1
    pad: int = 0
    sparsity: float = 0.0
    batch: int = 1
    dtype: str = "float32"
    relu: bool = False
    residual: bool = False

    @property
    def hp(self) -> int:
        return self.h + 2 * self.pad

    @property
    def wp(self) -> int:
        return self.w + 2 * self.pad

    @property
    def e(self) -> int:
        return (self.hp - self.r) // self.stride + 1

    @property
    def f(self) -> int:
        return (self.wp - self.s) // self.stride + 1

    @property
    def row_nnz_est(self) -> int:
        """Expected nonzeros per output channel at this sparsity."""
        return max(1, math.ceil(self.c * self.r * self.s * (1.0 - self.sparsity)))

    def k_est(self, pad_to: int) -> int:
        """Estimated padded ELL row length K for a given pad_to bucket."""
        pad_to = max(1, pad_to)
        k = self.row_nnz_est
        return max(pad_to, ((k + pad_to - 1) // pad_to) * pad_to)

    def bsr_grid(self, bm: int, bn: int) -> Tuple[int, int, int]:
        """(gbm, gbn, kept-per-row estimate) of a (bm, bn)-blocked bank.

        The kept estimate assumes block-structured pruning at this layer's
        sparsity (``core.pruning.block_prune_conv``) — the deal the BCSR
        path offers.  On unstructured-pruned weights nearly every tile
        survives and the real bank is denser than this estimate; execution
        stays correct, only slower than priced.
        """
        gbm = -(-self.m // bm)
        gbn = -(-(self.c * self.r * self.s) // bn)
        kept = min(gbn, max(1, math.ceil((1.0 - self.sparsity) * gbn)))
        return gbm, gbn, kept


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the customization space.

    tm/te/tf are only meaningful for the pallas method (te/tf = None means
    the untiled full-extent spatial schedule); pad_to only for the sparse
    formats (lowered / csr-direct / pallas); ``fuse`` only for pallas and
    bsr — True executes the epilogue in-kernel; ``pipeline`` only for
    pallas — True double-buffers the halo DMA; ``permute`` only for pallas
    — True runs an nnz-balanced bank with the inverse permutation applied
    to the output; ``block_m``/``block_n`` only for bsr — the BCSR tile
    shape (te/tf are meaningful for bsr too); ``value_dtype`` only for
    pallas and bsr — the bank's value-storage dtype ("float32", or the
    quantised "int8"/"float8_e4m3fn" with per-output-channel f32 scales
    and f32 accumulation).
    """

    method: str
    tm: Optional[int] = None
    pad_to: Optional[int] = None
    te: Optional[int] = None
    tf: Optional[int] = None
    fuse: bool = False
    pipeline: bool = False
    permute: bool = False
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    value_dtype: str = "float32"

    def to_dict(self) -> dict:
        return {"method": self.method, "tm": self.tm, "pad_to": self.pad_to,
                "te": self.te, "tf": self.tf, "fuse": self.fuse,
                "pipeline": self.pipeline, "permute": self.permute,
                "block_m": self.block_m, "block_n": self.block_n,
                "value_dtype": self.value_dtype}

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        return cls(method=d["method"], tm=d.get("tm"), pad_to=d.get("pad_to"),
                   te=d.get("te"), tf=d.get("tf"),
                   fuse=bool(d.get("fuse", False)),
                   pipeline=bool(d.get("pipeline", False)),
                   permute=bool(d.get("permute", False)),
                   block_m=d.get("block_m"), block_n=d.get("block_n"),
                   value_dtype=d.get("value_dtype", "float32"))


def pallas_feasible(g: ConvGeometry, k: int,
                    value_dtype: str = "float32") -> bool:
    """The Pallas kernel needs SMEM-resident packed indices (+ bias row, +
    the scale row for a quantised bank) and at least one VMEM-feasible
    (tm, te, tf) tiling at the bank's value width.  Stride is handled
    in-kernel."""
    vsize = value_itemsize(value_dtype)
    if not smem_fits(g.m, k, vsize == 1):
        return False
    return bool(tile_candidates(g.m, g.c, g.e, g.f, k, g.r, g.s, g.stride,
                                value_itemsize=vsize))


def bsr_feasible(g: ConvGeometry, bm: int, bn: int) -> bool:
    """The BCSR conv kernel needs its SMEM-resident block-column table and
    at least one VMEM-feasible (te, tf) spatial tiling for this block
    shape.

    The SMEM gate uses ``gbn`` — the largest KB any real bank of this
    geometry can pad to — not the mean kept estimate: the runtime check in
    ``ops.bsr_conv`` sees the bank's actual (max-row) KB, and a static
    gate below that bound could emit plans whose kernels silently fall
    back at execution time.
    """
    gbm, gbn, _ = g.bsr_grid(bm, bn)
    if not bsr_smem_fits(gbm, gbn):
        return False
    return bool(bsr_tile_candidates(g.c, g.e, g.f, g.r, g.s, g.stride,
                                    bm, bn))


def enumerate_candidates(g: ConvGeometry,
                         methods: Tuple[str, ...] = METHODS,
                         value_dtypes: Tuple[str, ...] = ("float32",),
                         ) -> List[Candidate]:
    """All statically-valid customization points for one layer.

    Every emitted pallas ``(tm, te, tf)`` fits the VMEM budget (via
    ``kernels.sparse_conv.ops.tile_candidates`` — the heuristic the tuner
    refines; the list is preference-sorted and capped at MAX_TILINGS); every
    pallas candidate fits the SMEM budget.  Pallas points enumerate the
    full schedule cross product: unfused and fused (in-kernel epilogue)
    variants — fused-residual tilings reserve VMEM for the shortcut input
    tile — each in blocking and double-buffered (``pipeline``) halo DMA
    flavours — pipelined tilings reserve VMEM for the second halo block,
    so their feasible sets can be smaller — and each tiling additionally in
    an nnz-balanced (``permute``) variant.  BSR points enumerate the block
    shape ladder x feasible spatial tilings x the fuse axis.

    ``value_dtypes`` is the value-storage axis: both Pallas paths enumerate
    each requested dtype with its own feasibility probe (a quantised bank's
    smaller value block can make tilings feasible that f32 busts, and its
    scale row tightens the SMEM gate).  The default is float32 only —
    narrow storage is lossy, so quantised candidates enter the space only
    when a caller opts in (``plan_layer(..., quantize=True)`` passes the
    backend-filtered ``allowed_value_dtypes``; fp8 is dropped off-TPU to
    keep unexecutable points out of the measured space).  Dense / lowered /
    csr-direct candidates stay float32 always.
    """
    if g.sparsity <= 0.0:
        # Dense-kept layers (paper: conv1 et al.) have no sparse format.
        return [Candidate("dense")]
    out: List[Candidate] = []
    if "dense" in methods:
        out.append(Candidate("dense"))
    if "bsr" in methods:
        itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
        for vdt in value_dtypes:
            vsize = value_itemsize(vdt)
            quantized = vsize == 1
            for bm, bn in BLOCK_CANDIDATES:
                # SMEM gate at gbn, the worst-case KB any real bank pads to —
                # the runtime check sees the actual (max-row) KB, and a
                # mean-estimate gate could emit plans that silently fall back.
                gbm, gbn, _ = g.bsr_grid(bm, bn)
                if not bsr_smem_fits(gbm, gbn):
                    continue
                for fuse in (False, True):
                    tilings = bsr_tile_candidates(
                        g.c, g.e, g.f, g.r, g.s, g.stride, bm, bn,
                        itemsize=itemsize,
                        fuse_res=fuse and g.residual,
                        value_itemsize=vsize,
                        quantized=quantized)[:MAX_TILINGS]
                    for te, tf in tilings:
                        out.append(Candidate("bsr", te=te, tf=tf, fuse=fuse,
                                             block_m=bm, block_n=bn,
                                             value_dtype=vdt))
    for pad_to in PAD_TO_BUCKETS:
        k = g.k_est(pad_to)
        if "lowered" in methods:
            out.append(Candidate("lowered", pad_to=pad_to))
        if "csr-direct" in methods:
            out.append(Candidate("csr-direct", pad_to=pad_to))
        if "pallas" not in methods:
            continue
        for vdt in value_dtypes:
            vsize = value_itemsize(vdt)
            if not smem_fits(g.m, k, vsize == 1):
                continue
            for fuse in (False, True):
                # Pipelined first: the scorer keeps the earliest candidate
                # on ties, and on memory-bound layers the two schedules'
                # roofline totals tie while the pipelined one strictly cuts
                # the VPU staging stall — never worse, so it wins ties.
                for pipe in (True, False):
                    tilings = tile_candidates(
                        g.m, g.c, g.e, g.f, k, g.r, g.s, g.stride,
                        fuse_res=fuse and g.residual,
                        pipeline=pipe, value_itemsize=vsize)[:MAX_TILINGS]
                    for tm, te, tf in tilings:
                        for permute in (False, True):
                            out.append(Candidate(
                                "pallas", tm=tm, pad_to=pad_to, te=te, tf=tf,
                                fuse=fuse, pipeline=pipe, permute=permute,
                                value_dtype=vdt))
    return out
