"""Abstract Escoin-BCSR weight trees for the serving dry-run (§Perf C).

At decode, weight bytes are the HBM-traffic floor; Escoin's thesis is that
pruning should buy speed, not just space.  This module rewrites the abstract
(ShapeDtypeStruct) parameter tree so every large projection is stored as a
``BcsrMatrix`` whose block count reflects the target sparsity — the compiled
serving step then *reads 1-sparsity of the weight bytes*, and the roofline
memory term shows exactly the win real pruned serving would get.

No weight values exist (dry-run): block counts are the deterministic
``ceil(tiles * density)``; correctness of the BCSR path itself is covered by
the kernel/system tests.

Block geometry: bm = M / tp so the block-row axis shards exactly tp ways
(jit in_shardings require divisibility); bn = 128 (lane width).
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.sparse_format import BcsrMatrix
from repro.models import transformer as T
from repro.models.config import ModelConfig

SKIP = {"embed", "lm_head", "router", "conv_w", "q_norm", "kv_norm"}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract_bcsr(m: int, n: int, dtype, tp: int, sparsity: float,
                   stack: Tuple[int, ...] = ()) -> Tuple[Any, Any]:
    """(BcsrMatrix of ShapeDtypeStructs, BcsrMatrix of PartitionSpecs) for a
    logical (M=out, N=in) weight, optionally layer-stacked."""
    bm = m // tp if (m % tp == 0 and m // tp >= 8) else m
    bn = 128 if n % 128 == 0 else n
    gm, gn = m // bm, n // bn
    kb = max(1, math.ceil(gn * (1.0 - sparsity)))
    lead = stack
    sd = BcsrMatrix(
        blocks=_sds(lead + (gm, kb, bm, bn), dtype),
        blockcol=_sds(lead + (gm, kb), jnp.int32),
        nblocks=_sds(lead + (gm,), jnp.int32),
        shape=(m, n), block=(bm, bn))
    row = ("tp",) if gm == tp else (None,)
    pre = (None,) * len(lead)
    sp = BcsrMatrix(
        blocks=P(*(pre + row + (None, None, None))),
        blockcol=P(*(pre + row + (None,))),
        nblocks=P(*(pre + row)),
        shape=(m, n), block=(bm, bn))
    return sd, sp


def abstract_sparse_params(cfg: ModelConfig, tp: int, sparsity: float,
                           min_dim: int = 512) -> Tuple[Any, Any]:
    """(abstract param tree, spec tree) with BCSR projections.

    Walks the dense abstract tree and its spec tree together; eligible dense
    leaves (2-D (in, out) or layer-stacked 3-D, both dims >= min_dim, not in
    SKIP) become abstract BcsrMatrix leaves over W^T.
    """
    dense = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = T.param_specs(cfg, tp)

    def convert(name, leaf, spec):
        if name in SKIP or not hasattr(leaf, "ndim"):
            return leaf, spec
        if leaf.ndim == 2 and min(leaf.shape) >= min_dim:
            return _abstract_bcsr(leaf.shape[1], leaf.shape[0], leaf.dtype,
                                  tp, sparsity)
        if leaf.ndim == 3 and min(leaf.shape[1:]) >= min_dim:
            return _abstract_bcsr(leaf.shape[2], leaf.shape[1], leaf.dtype,
                                  tp, sparsity, stack=(leaf.shape[0],))
        return leaf, spec

    def walk2(d, s):
        if isinstance(d, dict):
            out_d, out_s = {}, {}
            for k in d:
                if isinstance(d[k], (dict, list)):
                    out_d[k], out_s[k] = walk2(d[k], s[k])
                else:
                    out_d[k], out_s[k] = convert(k, d[k], s[k])
            return out_d, out_s
        if isinstance(d, list):
            pairs = [walk2(a, b) for a, b in zip(d, s)]
            return [p[0] for p in pairs], [p[1] for p in pairs]
        return d, s

    return walk2(dense, specs)
