import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape) against the
# production meshes, print memory/cost analysis, and emit roofline JSON.
#
# The two lines above MUST stay the first statements in this file: jax locks
# the device count at first init, and the dry-run needs 512 placeholder host
# devices.  Everything else (tests, benches) sees the normal single device.
#
# FLOPs/bytes accounting: XLA's HloCostAnalysis counts a while-loop body once
# regardless of trip count, so a scanned layer stack under-reports.  Each cell
# therefore does THREE compiles:
#   full   -- production scanned program: proves the cell compiles, gives
#             memory_analysis and compile stats;
#   probe1 -- 1-block model, every loop unrolled (flags.UNROLL);
#   probe2 -- 2-block model, ditto.
# Per-block cost = probe2 - probe1; full-depth cost = probe1 + (n-1)*delta.
# This is exact for the repeated stack (blocks are structurally identical).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --all-shapes --multi-pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all      # every cell, both meshes

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs as cfgs
from repro.distributed import sharding as shd
from repro.launch import roofline as rl
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (abstract_state, make_prefill_step,
                                make_serve_step, make_train_step,
                                state_shardings)
from repro.models import flags as F
from repro.models import transformer as T
from repro.optim import AdamWConfig

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

PROBE_ATTN_CHUNK = 8192   # fewer unrolled attention bodies; FLOPs invariant


def _compile_step(cfg, shape, mesh, *, remat, num_microbatches,
                  compress_cross_pod, sparse_weights: float = 0.0,
                  fsdp_axis: str = "data"):
    """Lower + compile one program; returns (compiled, lower_s, compile_s)."""
    tp = mesh.shape["model"]
    dp = mesh.shape["data"] * mesh.shape.get("pod", 1)
    F.set_remat(remat if shape.kind == "train" else "none")
    opt_cfg = AdamWConfig()
    in_sds, in_parts = S.input_specs(cfg, shape, tp, dp)
    rules = shd.default_rules(mesh)
    if fsdp_axis != "data":
        rules["fsdp"] = fsdp_axis   # §Perf: e.g. shard weights over the model
                                    # axis at decode (no per-step FSDP gather)
    with mesh:
        with shd.use_rules(rules, mesh):
            to_ns = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, shd.resolve(s)), tree,
                is_leaf=lambda s: isinstance(s, PartitionSpec))
            if shape.kind == "train":
                step = make_train_step(cfg, opt_cfg,
                                       num_microbatches=num_microbatches,
                                       compress_cross_pod=compress_cross_pod)
                state_ns = state_shardings(cfg, mesh, tp)
                jitted = jax.jit(step,
                                 in_shardings=(state_ns, to_ns(in_parts)),
                                 out_shardings=(state_ns, None),
                                 donate_argnums=(0,))
                args = (abstract_state(cfg, opt_cfg), in_sds)
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg)
                pspec_ns = to_ns(T.param_specs(cfg, tp))
                jitted = jax.jit(step, in_shardings=(pspec_ns, to_ns(in_parts)))
                args = (jax.eval_shape(
                    lambda: T.init_params(cfg, jax.random.PRNGKey(0))), in_sds)
            else:  # decode
                step = make_serve_step(cfg)
                if sparse_weights > 0:
                    # §Perf: Escoin BCSR weights at serving time
                    from repro.launch.sparse_weights import abstract_sparse_params
                    psds, pspecs = abstract_sparse_params(cfg, tp, sparse_weights)
                    pspec_ns = to_ns(pspecs)
                else:
                    psds = jax.eval_shape(
                        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
                    pspec_ns = to_ns(T.param_specs(cfg, tp))
                jitted = jax.jit(
                    step,
                    in_shardings=(pspec_ns, to_ns(in_parts["tokens"]),
                                  to_ns(in_parts["cache"]),
                                  to_ns(in_parts["cur_len"])),
                    out_shardings=(to_ns(in_parts["next_tokens"]),
                                   to_ns(in_parts["cache"])),
                    donate_argnums=(2,))
                args = (psds, in_sds["tokens"], in_sds["cache"],
                        in_sds["cur_len"])
            t0 = time.time()
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _probe_cfg(cfg, k: int):
    """Shallow variant with the prefix + k super-blocks."""
    prefix, period, _ = T.stage_plan(cfg)
    return dataclasses.replace(
        cfg, n_layers=cfg.first_dense_layers + k * max(len(period), 1))


def _cost_terms(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = rl.collective_bytes(compiled.as_text())
    return flops, hbm, coll


def _flash_analytic_flops(cfg, shape, n_dev: int) -> float:
    """Attention FLOPs hidden inside the flash custom-call (per device).

    HloCostAnalysis scores custom/emulated kernels ~0, so when ATTN_IMPL is
    flash we add the analytic attention flops: 4*B*H*hd*T_eff^2 per layer
    forward (qk + pv), x3 for train (bwd ~2x fwd), causal halves T^2.
    """
    if cfg.n_heads == 0:
        return 0.0
    kinds = cfg.layer_kinds()
    n_attn = sum(1 for k in kinds if k == "attn")
    t = shape.seq_len
    hd = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim if cfg.use_mla
          else cfg.head_dim)
    t_eff2 = t * t / (2 if cfg.causal else 1)
    per_layer = 4.0 * shape.global_batch * cfg.n_heads * hd * t_eff2
    mult = 3.0 if shape.kind == "train" else 1.0
    return n_attn * per_layer * mult / n_dev


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               remat: str = "dots", num_microbatches: int = 1,
               compress_cross_pod: bool = False, probes: bool = True,
               attn_impl: str = "chunked", moe_constrain: bool = False,
               moe_capacity: float = 1.25, sparse_weights: float = 0.0,
               moe_impl: str = "gather", fsdp_axis: str = "data",
               tag: str = "", verbose: bool = True):
    cfg = cfgs.get_config(arch)
    shape = cfgs.SHAPE_BY_NAME[shape_name]
    F.set_attn_impl(attn_impl)
    F.set_moe_constrain(moe_constrain)
    F.set_moe_capacity(moe_capacity)
    F.set_moe_impl(moe_impl)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    n_dev = mesh.devices.size

    # --- full production compile (scan stack): the dry-run proof ---
    F.set_unroll(False)
    F.set_attn_chunk(1024 if shape.seq_len <= 4096 else 4096)
    compiled, t_lower, t_compile = _compile_step(
        cfg, shape, mesh, remat=remat, num_microbatches=num_microbatches,
        compress_cross_pod=compress_cross_pod, sparse_weights=sparse_weights,
        fsdp_axis=fsdp_axis)
    mem = compiled.memory_analysis()
    raw_flops, raw_hbm, raw_coll = _cost_terms(compiled)

    # --- probe compiles (unrolled, shallow) for exact per-block costs ---
    flops, hbm, coll = raw_flops, raw_hbm, dict(raw_coll)
    probe_info = None
    _, period, nblocks = T.stage_plan(cfg)
    if probes and nblocks > 1:
        F.set_unroll(True)
        F.set_attn_chunk(PROBE_ATTN_CHUNK)
        c1, *_ = _compile_step(_probe_cfg(cfg, 1), shape, mesh, remat=remat,
                               num_microbatches=num_microbatches,
                               compress_cross_pod=compress_cross_pod,
                               sparse_weights=sparse_weights,
                               fsdp_axis=fsdp_axis)
        f1, h1, k1 = _cost_terms(c1)
        c2, *_ = _compile_step(_probe_cfg(cfg, 2), shape, mesh, remat=remat,
                               num_microbatches=num_microbatches,
                               compress_cross_pod=compress_cross_pod,
                               sparse_weights=sparse_weights,
                               fsdp_axis=fsdp_axis)
        f2, h2, k2 = _cost_terms(c2)
        F.set_unroll(False)
        # Clamp per-block deltas at 0: for tiny bodies (SSM decode) XLA's
        # optimizer can make the 2-block program cheaper than 2x the 1-block
        # one; extrapolating a negative delta would be nonsense.  Also floor
        # at the raw scanned counts (body-once) which are a strict lower bound.
        flops = max(f1 + (nblocks - 1) * max(f2 - f1, 0.0), raw_flops)
        hbm = max(h1 + (nblocks - 1) * max(h2 - h1, 0.0), raw_hbm)
        coll = {k: max(k1[k] + (nblocks - 1) * max(k2[k] - k1[k], 0), raw_coll[k])
                for k in k1}
        probe_info = {"probe1": {"flops": f1, "hbm": h1, "coll": k1},
                      "probe2": {"flops": f2, "hbm": h2, "coll": k2},
                      "nblocks": nblocks}

    flash_extra = (_flash_analytic_flops(cfg, shape, n_dev)
                   if attn_impl == "flash" else 0.0)
    r = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops=flops + flash_extra, hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=rl.model_flops_global(cfg, shape) / n_dev,
        peak_mem_bytes=float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                             + mem.output_size_in_bytes - mem.alias_size_in_bytes))
    if verbose:
        print(f"== {arch} x {shape_name} on mesh {mesh_name} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"   memory_analysis: {mem}")
        print(f"   flops/dev={r.flops:.3e} (raw scan {raw_flops:.3e})  "
              f"hbm/dev={r.hbm_bytes:.3e}  coll/dev={r.coll_bytes:.3e}")
        print(f"   t_compute={r.t_compute*1e3:.2f}ms  t_memory={r.t_memory*1e3:.2f}ms  "
              f"t_collective={r.t_collective*1e3:.2f}ms  -> {r.bottleneck}-bound")
        print(f"   useful_ratio={r.useful_ratio:.3f}  "
              f"roofline_fraction={r.roofline_fraction:.3f}")
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = r.to_dict()
    out.update({
        "lower_s": t_lower, "compile_s": t_compile,
        "raw_scan_flops": raw_flops, "raw_scan_hbm": raw_hbm,
        "probe_info": probe_info,
        "mem_arg_bytes": mem.argument_size_in_bytes,
        "mem_out_bytes": mem.output_size_in_bytes,
        "mem_temp_bytes": mem.temp_size_in_bytes,
        "mem_alias_bytes": mem.alias_size_in_bytes,
        "remat": remat, "num_microbatches": num_microbatches,
        "compress_cross_pod": compress_cross_pod,
        "attn_impl": attn_impl, "moe_constrain": moe_constrain,
        "sparse_weights": sparse_weights, "moe_impl": moe_impl,
        "fsdp_axis": fsdp_axis,
        "moe_capacity": moe_capacity, "flash_extra_flops": flash_extra,
    })
    suffix = f"__{tag}" if tag else ""
    path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    path.write_text(json.dumps(out, indent=2))
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all-shapes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", type=str, default="dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-cross-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--attn-impl", type=str, default="chunked",
                    choices=("chunked", "flash"))
    ap.add_argument("--moe-constrain", action="store_true")
    ap.add_argument("--moe-capacity", type=float, default=1.25)
    ap.add_argument("--sparse-weights", type=float, default=0.0)
    ap.add_argument("--moe-impl", type=str, default="gather",
                    choices=("gather", "ep"))
    ap.add_argument("--fsdp-axis", type=str, default="data",
                    choices=("data", "model"))
    ap.add_argument("--tag", type=str, default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, s in cfgs.all_cells():
            cells.append((arch, s.name))
    elif args.all_shapes:
        for s in cfgs.applicable_shapes(args.arch):
            cells.append((args.arch, s.name))
    else:
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        # big models need the aggressive checkpoint policy to have any chance
        # of fitting HBM; small models keep the cheaper dots policy
        remat = ("full" if cfgs.get_config(arch).num_params() > 5e10
                 else args.remat)
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            path = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}{suffix}.json"
            if args.skip_existing and path.exists():
                print(f"skip existing {path.name}")
                continue
            try:
                # probes only on the single-pod mesh: the §Roofline table is
                # single-pod; the multi-pod pass proves compilation + pod-axis
                # sharding (raw scanned counts recorded).
                lower_cell(arch, shape, multi_pod=mp, remat=remat,
                           num_microbatches=args.microbatches,
                           compress_cross_pod=args.compress_cross_pod,
                           probes=(not args.no_probes) and not mp,
                           attn_impl=args.attn_impl,
                           moe_constrain=args.moe_constrain,
                           moe_capacity=args.moe_capacity,
                           sparse_weights=args.sparse_weights,
                           moe_impl=args.moe_impl, fsdp_axis=args.fsdp_axis,
                           tag=args.tag)
            except Exception:
                failures.append((arch, shape, mesh_name))
                traceback.print_exc()
    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cell(s) x {len(meshes)} mesh(es)")


if __name__ == "__main__":
    main()
