"""Step builders: train_step / prefill_step / serve_step with shardings.

These are the functions the dry-run lowers against the production meshes and
the drivers run on real hardware.  All distribution is expressed through
(in|out)_shardings + logical-axis constraints inside the model; XLA SPMD
inserts the collectives.

Distributed-optimization knobs (DESIGN.md §5):
  * num_microbatches > 1     -- gradient accumulation; the per-microbatch
                                reduce-scatter overlaps the next microbatch's
                                compute inside the scan.
  * compress_cross_pod=True  -- int8 error-feedback all-reduce over the "pod"
                                (DCN) axis via partial shard_map.
  * remat                    -- activation checkpoint policy for the stack.
  * donate                   -- state/cache buffers are donated (in-place).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


def _ns(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, shd.resolve(spec))


def state_shardings(cfg: ModelConfig, mesh, tp: int) -> Dict[str, Any]:
    pspecs = T.param_specs(cfg, tp)
    to_ns = lambda tree: jax.tree.map(
        lambda s: _ns(mesh, s), tree, is_leaf=lambda s: isinstance(s, P))
    params_ns = to_ns(pspecs)
    return {
        "params": params_ns,
        "opt": {"m": params_ns, "v": params_ns,
                "step": _ns(mesh, P())},
    }


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    num_microbatches: int = 1,
                    compress_cross_pod: bool = False,
                    total_steps: int = 100_000,
                    ) -> Callable[..., Tuple[Dict, Dict]]:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_of(params, batch):
        return T.loss_fn(params, batch.get("tokens"), batch["labels"], cfg,
                         embeds=batch.get("embeds"))

    def grads_of(params, batch):
        if num_microbatches == 1:
            return jax.value_and_grad(loss_of)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % num_microbatches == 0, (b, num_microbatches)
            return x.reshape((num_microbatches, b // num_microbatches)
                             + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_of)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(acc_step, (jnp.float32(0), g0), micro)
        inv = 1.0 / num_microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, grads = grads_of(params, batch)
        if compress_cross_pod:
            from repro.optim.compression import compressed_psum_tree
            mesh = shd.get_mesh()
            if mesh is not None and "pod" in mesh.axis_names:
                # Per-pod partial gradients were already reduced in-pod by
                # SPMD; quantise the cross-pod hop explicitly.
                grads = jax.shard_map(
                    lambda g: compressed_psum_tree(
                        jax.tree.map(lambda x: x / jax.lax.psum(1.0, "pod"), g),
                        "pod"),
                    mesh=mesh,
                    in_specs=(jax.tree.map(lambda _: P(), grads),),
                    out_specs=jax.tree.map(lambda _: P(), grads),
                    axis_names={"pod"}, check_vma=False,
                )(grads)
        lr = cosine_schedule(opt["step"], peak=opt_cfg.lr,
                             warmup=min(2000, max(1, total_steps // 10)),
                             total=total_steps)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt, opt_cfg, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": new_opt["step"]}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """prefill_step(params, batch) -> (last-position logits, final hidden).

    The final hidden state is returned so the full stack has a live consumer;
    production prefill would additionally emit the KV cache (same compute).
    """

    def prefill_step(params, batch):
        if "embeds" in batch:
            h, _ = T.hidden_embeds(params, batch["embeds"], cfg)
        else:
            emb = jnp.take(params["embed"], batch["tokens"], axis=0)
            h, _ = T.hidden_embeds(params, emb.astype(jnp.dtype(cfg.dtype)), cfg)
        logits = T._head(params, cfg, h[:, -1:])
        return logits[:, 0], h

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, tokens, cache, cur_len) -> (next token ids, cache)."""

    def serve_step(params, tokens, cache, cur_len):
        logits, new_cache = T.decode_step(params, cfg, tokens, cache, cur_len)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    return serve_step


def init_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key) -> Dict[str, Any]:
    params = T.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def abstract_state(cfg: ModelConfig, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct state for lowering (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_state, cfg, opt_cfg), jax.random.PRNGKey(0))
