"""Training driver: end-to-end loop with checkpoint/restart + monitoring.

Runs any registered arch on whatever devices exist (CPU-runnable with smoke
configs; the same code path lowers against the production meshes).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs as cfgs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, make_loader
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import init_state, make_train_step, state_shardings
from repro.models import flags as F
from repro.optim import AdamWConfig
from repro.runtime import StepRunner, StragglerMonitor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", type=str, default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--remat", type=str, default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch, smoke=args.smoke)
    F.set_remat(args.remat)
    mesh = make_host_mesh(model=args.model_axis)
    tp = mesh.shape["model"]
    opt_cfg = AdamWConfig(lr=args.lr)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab, seed=args.seed,
                      embed_dim=cfg.d_model if cfg.family in ("vlm", "encoder")
                      else 0)

    with mesh:
        with shd.use_rules(shd.default_rules(mesh), mesh):
            state_ns = state_shardings(cfg, mesh, tp)
            step_fn = make_train_step(cfg, opt_cfg,
                                      num_microbatches=args.microbatches,
                                      total_steps=args.steps)
            jit_step = jax.jit(step_fn, in_shardings=(state_ns, None),
                               out_shardings=(state_ns, None),
                               donate_argnums=(0,))
            state = init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
            state = jax.device_put(state, state_ns)

            ckpt = CheckpointManager(args.ckpt_dir or "/tmp/repro_ckpt", keep=2)
            restored, ck_step = (ckpt.restore_latest(
                jax.eval_shape(lambda s: s, state), shardings=state_ns)
                if args.ckpt_dir else (None, None))
            start = 0
            if restored is not None:
                state, start = restored, ck_step
                print(f"resumed from step {start}")

            def to_device(batch):
                return {k: jax.device_put(
                    v, NamedSharding(mesh, shd.resolve(
                        PartitionSpec(*(("dp",) + (None,) * (v.ndim - 1))))))
                    for k, v in batch.items()}

            def step_and_log(st, batch):
                st, m = jit_step(st, to_device(batch))
                return st, m

            runner = StepRunner(step_and_log, ckpt,
                                lambda s: make_loader(dcfg, s),
                                ckpt_every=args.ckpt_every,
                                monitor=StragglerMonitor())
            t0 = time.time()
            losses = []

            def on_metrics(step, m):
                losses.append(m.get("loss", float("nan")))
                if step % 5 == 0 or step == start + 1:
                    print(f"step {step}: loss={m.get('loss'):.4f} "
                          f"gnorm={m.get('grad_norm'):.3f} lr={m.get('lr'):.2e}")

            state, end = runner.run(state, start, args.steps,
                                    on_metrics=on_metrics)
            dt = time.time() - t0
            k = min(5, len(losses))
            first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
            print(f"trained {end - start} steps in {dt:.1f}s "
                  f"({dt / max(end - start, 1):.2f}s/step); "
                  f"loss {first:.4f} -> {last:.4f}")
            if not np.isfinite(last):
                raise SystemExit("loss diverged — check config")
            if len(losses) >= 50 and last > first + 0.05:
                raise SystemExit("loss did not improve — check config")


if __name__ == "__main__":
    main()
