"""Serving driver: batched prefill + decode with KV cache (+ Escoin sparsity).

With --sparsity > 0, every linear weight is magnitude/block pruned and served
through the Escoin BCSR path (the paper's technique as a serving feature).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --sparsity 0.8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.core.pruning import block_prune
from repro.core.sparse_format import bcsr_from_dense, bcsr_stack_from_dense
from repro.launch.steps import make_serve_step
from repro.models import transformer as T


def sparsify_params(params, cfg, sparsity: float, block=(16, 16), min_dim=64):
    """Prune + convert every large 2-D linear weight to Escoin BCSR."""
    def visit(p):
        if isinstance(p, dict):
            return {k: (visit(v) if isinstance(v, (dict, list)) else conv(k, v))
                    for k, v in p.items()}
        if isinstance(p, list):
            return [visit(v) for v in p]
        return p

    skip = {"embed", "lm_head", "router", "conv_w"}

    def conv(name, w):
        if name in skip or not hasattr(w, "ndim"):
            return w
        if w.ndim == 2 and min(w.shape) >= min_dim:
            pruned = block_prune(w.astype(jnp.float32), sparsity, block)
            # stored as (in, out); BCSR computes x @ W.T for (out, in)
            return bcsr_from_dense(np.asarray(pruned).T, block)
        if w.ndim == 3 and min(w.shape[1:]) >= min_dim:
            # stacked (L, in, out) weight inside the scanned stack
            pruned = jax.vmap(lambda m: block_prune(m, sparsity, block))(
                w.astype(jnp.float32))
            return bcsr_stack_from_dense(
                np.asarray(pruned).transpose(0, 2, 1), block)
        return w

    return visit(params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfgs.get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    if args.sparsity > 0:
        params = sparsify_params(params, cfg, args.sparsity)
        print(f"serving with Escoin BCSR weights at sparsity {args.sparsity}")

    b, p, g = args.batch, args.prompt_len, args.gen
    max_len = p + g
    prompts = jax.random.randint(key, (b, p), 0, cfg.vocab, jnp.int32)
    cache = T.init_cache(cfg, b, max_len)
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    # prefill token-by-token (smoke-scale; production uses the prefill step)
    t0 = time.time()
    tok = prompts[:, :1]
    for i in range(p):
        nxt, cache = serve_step(params, prompts[:, i:i + 1], cache,
                                jnp.int32(i))
    t_prefill = time.time() - t0

    out = [nxt]
    t0 = time.time()
    for i in range(p, p + g - 1):
        nxt, cache = serve_step(params, out[-1][:, None], cache, jnp.int32(i))
        out.append(nxt)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    assert gen.shape == (b, g), gen.shape
    assert np.isfinite(gen).all()
    print(f"generated {g} tokens x {b} seqs; prefill {t_prefill:.2f}s, "
          f"decode {t_decode:.2f}s ({t_decode / max(g - 1, 1) * 1e3:.1f} ms/tok)")
    print("sample:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
