"""Serving driver: batched prefill + decode with KV cache (+ Escoin sparsity).

With --sparsity > 0, every linear weight is magnitude/block pruned and served
through the Escoin BCSR path (the paper's technique as a serving feature).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --batch 4 --prompt-len 32 --gen 16 --sparsity 0.8

With --autotune, the kernel-customization autotuner (repro.tuning) plans a
CNN workload instead: per-layer method/tile selection, persisted to a JSON
plan cache, verified by a reload round-trip and an auto-vs-dense numeric
check on a reduced layer slice.

  PYTHONPATH=src python -m repro.launch.serve --smoke --autotune \
      [--cnn alexnet] [--plan-cache plans/autotune_cache.json]

With --cnn-serve, the fault-tolerant bucketed CNN serving loop
(repro.serving.robust) serves a seeded arrival trace on a reduced network
slice and prints the SLO summary; --chaos adds seeded fault injection
(repro.serving.chaos) and asserts zero lost requests plus recorded
degradation evidence.

  PYTHONPATH=src python -m repro.launch.serve --cnn-serve --chaos \
      [--cnn googlenet] [--chaos-seed 0] [--requests 40]
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro import telemetry
from repro.core.pruning import block_prune
from repro.core.sparse_format import bcsr_from_dense, bcsr_stack_from_dense
from repro.launch.steps import make_serve_step
from repro.models import transformer as T


def sparsify_params(params, cfg, sparsity: float, block=(16, 16), min_dim=64):
    """Prune + convert every large 2-D linear weight to Escoin BCSR.

    ``conv`` must fire on *every* array leaf — including leaves held in
    lists/tuples and an array at the pytree root (converting only
    dict-valued parents silently served those weights dense).
    """
    def visit(p, name=""):
        if isinstance(p, dict):
            return {k: visit(v, k) for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(visit(v, name) for v in p)
        return conv(name, p)

    skip = {"embed", "lm_head", "router", "conv_w"}

    def conv(name, w):
        if name in skip or not hasattr(w, "ndim"):
            return w
        if w.ndim == 2 and min(w.shape) >= min_dim:
            pruned = block_prune(w.astype(jnp.float32), sparsity, block)
            # stored as (in, out); BCSR computes x @ W.T for (out, in)
            return bcsr_from_dense(np.asarray(pruned).T, block)
        if w.ndim == 3 and min(w.shape[1:]) >= min_dim:
            # stacked (L, in, out) weight inside the scanned stack
            pruned = jax.vmap(lambda m: block_prune(m, sparsity, block))(
                w.astype(jnp.float32))
            return bcsr_stack_from_dense(
                np.asarray(pruned).transpose(0, 2, 1), block)
        return w

    return visit(params)


def autotune_main(args) -> None:
    """CNN autotune flow: lower -> plan -> persist -> reload round-trip ->
    numeric check, all on the compile-once graph engine."""
    from repro.engine import CnnEngine, lower
    from repro.models import cnn
    from repro.tuning import (PlanCache, apply_plan_to_params, format_plan,
                              plan_program)

    name = args.cnn
    net = cnn.NETWORKS[name]()
    image = ({"alexnet": 99, "googlenet": 96, "resnet50": 96}[name]
             if args.smoke else 224)
    mode = args.tune_mode
    params = None
    rng = np.random.default_rng(args.seed)
    if mode == "wall":
        params = cnn.init_cnn(net, 3, rng, image)

    program = lower(net, (3, image, image))
    cache = PlanCache(args.plan_cache)
    plan = plan_program(program, batch=1, mode=mode, cache=cache,
                        params=params)
    fused = sum(pe.method in ("pallas", "bsr") and pe.fuse
                for pe in plan.values())
    print(f"tuned {name} @ {image}px: {program.summary()}; "
          f"{len(plan)} conv layers ({fused} fused-epilogue kernels), "
          f"{len(cache)} cache entries -> {args.plan_cache}")
    print(format_plan(plan))

    # Round-trip: a fresh cache loaded from disk must reproduce the plan
    # without re-tuning (every layer a hit).
    replan = plan_program(program, batch=1, mode=mode,
                          cache=PlanCache(args.plan_cache), params=params)
    assert replan == plan, "plan cache reload did not reproduce the plan"
    print(f"plan cache round-trip ok ({args.plan_cache})")

    # Numeric check: auto dispatch vs the dense oracle on a reduced-channel
    # slice of the network — the first dense-kept conv plus the first two
    # sparse convs (interpret-mode Pallas stays tractable on CPU).
    convs = [l for l, _ in program.conv_table]
    picked = ([next(l for l in convs if l.sparsity == 0)]
              + [l for l in convs if l.sparsity > 0][:2])
    slice_net = []
    for l in picked:
        slice_net.append(dataclasses.replace(
            l, out_c=max(8, min(32, l.out_c // 8)), stride=1))
        slice_net.append(cnn.Relu())
    slice_prog = lower(slice_net, (3, 12, 12))
    sparams = cnn.init_cnn(slice_net, 3, rng, 12)
    x = jnp.asarray(rng.standard_normal((1, 3, 12, 12)).astype(np.float32))
    # Fresh in-memory cache: the synthetic slice geometries must not be
    # persisted into the deployment plan cache.
    splan = plan_program(slice_prog, batch=1, mode="roofline",
                         cache=PlanCache())
    apply_plan_to_params(sparams, splan)
    engine = CnnEngine(slice_prog, sparams, splan)
    y_auto = engine(x, "auto")
    # Capture the auto forward's report before the dense oracle forward
    # overwrites last_report with its own.
    report = engine.last_report if telemetry.is_enabled() else None
    y_dense = engine(x, "dense")
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)
    methods = sorted({pe.method for pe in splan.values()})
    print(f"auto-vs-dense slice check ok (slice methods: {', '.join(methods)})")

    if report is not None:
        # The auto forward above recorded its per-op ExecutionReport at
        # dispatch time; surface it (and fail loudly on silent fallbacks).
        print(report.format())
        assert report.fallback_count == 0, (
            f"traced forward took {report.fallback_count} silent "
            f"fallback(s): {[o.fallback_reason for o in report.fallback_ops]}")


def cnn_serve_main(args) -> None:
    """Robust CNN serving flow: shape-bucketed admission + degradation
    ladder over a reduced network slice, driven by a seeded arrival trace
    on a virtual clock (deterministic; interpret-mode Pallas stays
    tractable on CPU).  ``--chaos`` turns on seeded fault injection — the
    run must still terminate every request (zero lost) and must leave
    degradation evidence (a ladder step-down or a dropped rung)."""
    from repro.engine import init_conv_params, lower
    from repro.serving import (BucketSpec, ChaosConfig, ChaosInjector,
                               RobustCnnServer, VirtualClock, arrival_trace,
                               slice_net)

    name = args.cnn
    net = slice_net(name)
    rng = np.random.default_rng(args.seed)
    params = init_conv_params(lower(net, (3, 12, 12)), rng)
    chaos = None
    if args.chaos:
        chaos = ChaosInjector(ChaosConfig(
            seed=args.chaos_seed, step_fault_rate=0.35,
            plan_corruption_rate=0.5, straggler_rate=0.1))
    server = RobustCnnServer(
        net, params,
        [BucketSpec(3, 12, 12, batch=2), BucketSpec(3, 16, 16, batch=2)],
        clock=VirtualClock(), queue_depth=16, max_attempts=6,
        cooldown_ticks=4, chaos=chaos)
    trace = arrival_trace(
        args.requests, [(3, 12, 12), (3, 10, 10), (3, 16, 16)],
        seed=args.seed, mean_gap_s=0.0005, deadline_s=(1.0, 2.0))
    ladder = {b.spec.key: [r.name for r in b.rungs] for b in server._buckets}
    print(f"serving {name} slice: {args.requests} requests over "
          f"{len(ladder)} buckets; ladders {ladder}"
          + (f"; chaos seed {args.chaos_seed}" if chaos else ""))
    rep = server.run_trace(trace)
    print(rep.format())
    rep.verify()  # zero lost, zero duplicated — or raise
    if chaos is not None:
        print("chaos:", chaos.summary())
        assert rep.degradations or rep.dropped_rungs, (
            "chaos run left no degradation evidence (no ladder step-down, "
            "no dropped rung) — injection did not exercise the ladder")
    print(f"slo ok: {rep.completed}/{rep.submitted} served, "
          f"{rep.rejected_total} shed with reasons, 0 lost")


def export_trace(path: str) -> None:
    """Validate + write the global tracer's Chrome-trace JSON and a metrics
    summary — what ``--trace out.json`` produces."""
    tracer = telemetry.get_tracer()
    tracer.export(path)
    print(f"exported {len(tracer)} trace events -> {path} "
          f"({len(telemetry.snapshot())} metrics recorded)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="run the kernel-customization autotuner (CNN path)")
    ap.add_argument("--cnn", default="alexnet",
                    choices=("alexnet", "googlenet", "resnet50"))
    ap.add_argument("--plan-cache", default="plans/autotune_cache.json")
    ap.add_argument("--tune-mode", default="roofline",
                    choices=("roofline", "wall"))
    ap.add_argument("--trace", metavar="OUT_JSON",
                    help="enable telemetry and export a Chrome-trace JSON "
                         "(chrome://tracing / Perfetto) on exit")
    ap.add_argument("--cnn-serve", action="store_true",
                    help="run the fault-tolerant bucketed CNN serving loop "
                         "(repro.serving.robust) on a reduced slice")
    ap.add_argument("--chaos", action="store_true",
                    help="with --cnn-serve: seeded fault injection "
                         "(repro.serving.chaos)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=40,
                    help="with --cnn-serve: arrival-trace length")
    args = ap.parse_args()

    if args.trace:
        telemetry.enable()

    if args.autotune:
        autotune_main(args)
        if args.trace:
            export_trace(args.trace)
        return
    if args.cnn_serve:
        cnn_serve_main(args)
        if args.trace:
            export_trace(args.trace)
        return
    if not args.arch:
        ap.error("--arch is required unless --autotune is given")

    cfg = cfgs.get_config(args.arch, smoke=args.smoke)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    if args.sparsity > 0:
        params = sparsify_params(params, cfg, args.sparsity)
        print(f"serving with Escoin BCSR weights at sparsity {args.sparsity}")

    b, p, g = args.batch, args.prompt_len, args.gen
    max_len = p + g
    prompts = jax.random.randint(key, (b, p), 0, cfg.vocab, jnp.int32)
    cache = T.init_cache(cfg, b, max_len)
    serve_step = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    def span(name, **kw):
        if telemetry.is_enabled():
            return telemetry.get_tracer().span(name, cat="serve", **kw)
        return contextlib.nullcontext()

    # prefill token-by-token (smoke-scale; production uses the prefill step)
    t0 = time.time()
    with span("prefill", tokens=p, batch=b):
        for i in range(p):
            nxt, cache = serve_step(params, prompts[:, i:i + 1], cache,
                                    jnp.int32(i))
        jax.block_until_ready(nxt)
    t_prefill = time.time() - t0

    out = [nxt]
    t0 = time.time()
    with span("decode", tokens=g - 1, batch=b):
        for i in range(p, p + g - 1):
            nxt, cache = serve_step(params, out[-1][:, None], cache,
                                    jnp.int32(i))
            out.append(nxt)
        jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    assert gen.shape == (b, g), gen.shape
    assert np.isfinite(gen).all()
    print(f"generated {g} tokens x {b} seqs; prefill {t_prefill:.2f}s, "
          f"decode {t_decode:.2f}s ({t_decode / max(g - 1, 1) * 1e3:.1f} ms/tok)")
    print("sample:", gen[0, :12].tolist())
    if args.trace:
        export_trace(args.trace)


if __name__ == "__main__":
    main()
