"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (per-step, per-chip —
the optimized HLO module is already the per-device SPMD program):

  compute    = HLO_FLOPs / peak_FLOPs          (197 TF/s bf16, TPU v5e-class)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

collective_bytes is not in cost_analysis(): we parse the optimized HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

# Bytes/element the roofline charges for one sparse-bank value, by storage
# dtype name ("float32", "bfloat16", "int8", "float8_e4m3fn", ...).  Lives
# in ``repro.kernels.budget`` (the VMEM/SMEM fit arithmetic needs the same
# widths); re-exported here because this module is where traffic is priced:
# a quantised value stream is charged ``n_values * value_itemsize(dtype)``
# plus the per-output-channel f32 scale row — the byte credit that makes
# int8 halve (and fp8 quarter) the dominant sparse-conv traffic term.
from repro.kernels.budget import (VALUE_ITEMSIZES,  # noqa: F401
                                  value_itemsize)

PEAK_FLOPS = 197e12        # bf16 per chip (MXU systolic arrays)
# VPU (8x128 vector unit) FMA throughput, as a coarse architectural ratio of
# the MXU peak.  The per-nonzero FMA loops of the sparse direct/SpMM paths
# issue on the VPU, not the systolic arrays — pricing them at PEAK_FLOPS
# (the pre-BCSR model) hid the MXU-vs-VPU crossover that makes block
# sparsity worthwhile at moderate densities.
VPU_FLOPS = PEAK_FLOPS / 8
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result type at the start of an HLO instruction, e.g.
#   %x = bf16[16,2048]{1,0} all-gather(...)
# or tuple results: (f32[8,128], f32[8,128]) all-to-all(...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z0-9\-]+)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals from optimized (post-SPMD) HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                out[kind] += _shape_bytes(type_str)
                break
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device HLO bytes accessed
    coll_bytes: float             # per-device collective bytes (sum)
    coll_breakdown: Dict[str, int]
    model_flops: float            # analytic useful flops, per device
    peak_mem_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline lower bound on step time (no overlap assumption: max)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the useful model flops achieve at the bound."""
        if self.step_time == 0:
            return 0.0
        return (self.model_flops / self.step_time) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "peak_mem_bytes": self.peak_mem_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(arch: str, shape: str, mesh_name: str, compiled,
            model_flops_global: float, n_devices: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # some backends return [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        peak_mem = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=flops, hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        model_flops=model_flops_global / n_devices,
        peak_mem_bytes=peak_mem)


def model_flops_global(cfg, shape) -> float:
    """Analytic 'useful' FLOPs per step: 6*N_active*tokens (train) or
    2*N_active*tokens (inference); attention-score flops excluded (they are
    reported via the useful-ratio discussion instead)."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def save(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=2)
