"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape) cell.

No device allocation ever happens here — these feed ``jit(...).lower()``.
[audio]/[vlm] archs take precomputed frame/patch embeddings (frontend stub).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeConfig

STUB_EMBED_FAMILIES = ("vlm", "encoder")   # modality frontend is a stub


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(ShapeDtypeStructs, logical PartitionSpecs) for one train batch."""
    b, t = shape.global_batch, shape.seq_len
    if cfg.family in STUB_EMBED_FAMILIES:
        specs = {"embeds": sds((b, t, cfg.d_model), cfg.dtype),
                 "labels": sds((b, t), jnp.int32)}
        parts = {"embeds": P("dp", "sp", None), "labels": P("dp", "sp")}
    else:
        specs = {"tokens": sds((b, t), jnp.int32),
                 "labels": sds((b, t), jnp.int32)}
        parts = {"tokens": P("dp", "sp"), "labels": P("dp", "sp")}
    return specs, parts


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig
                        ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    b, t = shape.global_batch, shape.seq_len
    if cfg.family in STUB_EMBED_FAMILIES:
        return ({"embeds": sds((b, t, cfg.d_model), cfg.dtype)},
                {"embeds": P("dp", "sp", None)})
    return ({"tokens": sds((b, t), jnp.int32)}, {"tokens": P("dp", "sp")})


def _drop_batch_axis(parts):
    """Replace the leading 'dp' entry with None on every spec (batch size not
    divisible by the dp extent, e.g. long_500k's global_batch=1 — jit
    in_shardings require divisibility, unlike sharding constraints)."""
    def fix(spec: P) -> P:
        return P(*(None if e == "dp" else e for e in tuple(spec)))
    return jax.tree.map(fix, parts, is_leaf=lambda s: isinstance(s, P))


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                       dp: int = 1) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """tokens (B, 1) + full KV/SSM cache of seq_len + cur_len scalar."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, s))
    cache_parts = T.cache_specs(cfg, tp)
    specs = {"tokens": sds((b, 1), jnp.int32), "cache": cache,
             "cur_len": sds((), jnp.int32)}
    parts = {"tokens": P("dp", None), "cache": cache_parts, "cur_len": P(),
             "next_tokens": P("dp")}
    if dp and b % dp:
        parts = _drop_batch_axis(parts)
    return specs, parts


def input_specs(cfg: ModelConfig, shape: ShapeConfig, tp: int, dp: int = 1
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape, tp, dp)
