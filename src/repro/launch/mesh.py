"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: 16x16 = 256 chips (data, model).  Multi-pod:
2x16x16 = 512 chips (pod, data, model); the pod axis crosses DCN.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for mesh {shape}, have {len(devs)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    # e.g. 512 forced host devices, single-pod mesh uses the first 256
    return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh / tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Whatever this host has (CPU tests / examples): (data, model)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
