"""Probe-enrichment pass: re-run single-pod cells whose JSON lacks probe
extrapolation (probe_info == null), in priority order (train > prefill >
decode; small archs first so the table fills fastest).

  PYTHONPATH=src python -m repro.launch.enrich [--max-cells N]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import traceback

from repro import configs as cfgs
from repro.launch.dryrun import RESULTS_DIR, lower_cell

KIND_PRIORITY = {"train": 0, "prefill": 1, "decode": 2}


def pending():
    cells = []
    for arch, shape in cfgs.all_cells():
        path = RESULTS_DIR / f"{arch}__{shape.name}__16x16.json"
        if path.exists():
            d = json.loads(path.read_text())
            if d.get("probe_info"):
                continue
        cells.append((arch, shape))
    cells.sort(key=lambda c: (KIND_PRIORITY[c[1].kind],
                              cfgs.get_config(c[0]).num_params()))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-cells", type=int, default=1000)
    args = ap.parse_args()
    todo = pending()
    print(f"{len(todo)} cells pending probe enrichment")
    for arch, shape in todo[: args.max_cells]:
        remat = "full" if cfgs.get_config(arch).num_params() > 5e10 else "dots"
        try:
            lower_cell(arch, shape.name, multi_pod=False, remat=remat,
                       probes=True)
        except Exception:
            traceback.print_exc()


if __name__ == "__main__":
    main()
