"""The engine's flat, typed op program — what ``lower()`` emits.

A program is a topologically-ordered tuple of ops over SSA-style value ids:
value 0 is the network input, each op reads its ``src`` id(s) and defines
``out``.  All geometries (channels, spatial extents, FC fan-in) are resolved
statically at lowering time, so executing a program never inspects shapes or
re-walks the nested spec, and every ``jax.jit`` trace of a program is pure
dataflow.

``ConvOp`` carries the fused epilogue: ``fuse_relu`` marks a ``Conv → ReLU``
chain collapsed at lowering time, and ``res`` names the shortcut value of a
bottleneck tail (``Conv → (+shortcut) → ReLU``), so the executor can hand
the whole chain to the Pallas kernel's in-kernel epilogue and write the
output once from the f32 accumulator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

from repro.engine import spec


@dataclasses.dataclass(frozen=True)
class ConvOp:
    """One convolution with its statically-resolved geometry + epilogue.

    c/h/w: input channels and spatial dims; m/k/stride/pad: filter bank;
    e/f: output spatial dims.  The bias add is always part of the op (every
    conv layer carries a bias); ``fuse_relu``/``res`` extend the epilogue.
    """

    name: str
    src: int
    out: int
    c: int
    h: int
    w: int
    m: int
    k: int
    stride: int
    pad: int
    sparsity: float
    e: int
    f: int
    fuse_relu: bool = False
    res: Optional[int] = None     # shortcut value id added before the ReLU


@dataclasses.dataclass(frozen=True)
class PoolOp:
    kind: str                     # max | avg | gap
    k: int
    stride: int
    pad: int
    src: int
    out: int
    e: int
    f: int


@dataclasses.dataclass(frozen=True)
class FCOp:
    """Fully-connected layer with its fan-in resolved at lowering time.

    ``in_f`` is the static flattened input dim — FC weights are created at
    engine *bind* time from this, never lazily inside a trace.
    """

    name: str
    src: int
    out: int
    in_f: int
    out_f: int


@dataclasses.dataclass(frozen=True)
class ConcatOp:
    srcs: Tuple[int, ...]
    out: int


@dataclasses.dataclass(frozen=True)
class ResidualAddOp:
    """Shortcut add that could not be fused into a conv (body not ending in
    a Conv); ``a`` is the body output, ``b`` the shortcut."""

    a: int
    b: int
    out: int
    fuse_relu: bool = False


@dataclasses.dataclass(frozen=True)
class ReluOp:
    """A ReLU that did not fuse into a preceding conv (e.g. after an FC)."""

    src: int
    out: int


OpT = Any  # union of the op dataclasses above


@dataclasses.dataclass(frozen=True)
class Program:
    """A lowered network: flat ops + the spec-order conv table.

    ``conv_table`` lists ``(Conv spec, (C, H, W) input shape)`` in the same
    order the historical spec walkers visited convs (Residual: body then
    proj) — it drives parameter init and the benchmark shape tables, while
    ``ops`` is the (topological) execution order.
    """

    ops: Tuple[OpT, ...]
    out: int
    in_shape: Tuple[int, int, int]
    conv_table: Tuple[Tuple[spec.Conv, Tuple[int, int, int]], ...]

    @property
    def conv_ops(self) -> Tuple[ConvOp, ...]:
        return tuple(op for op in self.ops if isinstance(op, ConvOp))

    @property
    def fc_ops(self) -> Tuple[FCOp, ...]:
        return tuple(op for op in self.ops if isinstance(op, FCOp))

    def summary(self) -> str:
        counts: dict = {}
        fused = 0
        for op in self.ops:
            counts[type(op).__name__] = counts.get(type(op).__name__, 0) + 1
            if isinstance(op, ConvOp) and (op.fuse_relu or op.res is not None):
                fused += 1
        parts = [f"{k}x{v}" for k, v in sorted(counts.items())]
        return (f"{len(self.ops)} ops ({', '.join(parts)}), "
                f"{fused} convs with fused epilogue")
