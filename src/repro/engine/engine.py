"""CnnEngine: bind params + a tuned plan to a lowered program and execute.

The compile-once executor: a :class:`~repro.engine.program.Program` (from
``lower()``) plus a parameter dict plus an optional tuned plan, executed
through a cached ``jax.jit`` per (method, input geometry, fuse override).
Nothing here walks the nested spec and nothing mutates ``params`` inside a
trace — FC weights are created once at *bind* time from each ``FCOp``'s
statically-resolved fan-in.

Conv epilogues (``bias → ReLU`` and bottleneck ``bias → +shortcut → ReLU``)
were fused into ``ConvOp`` at lowering time; for the Pallas method they are
executed *in-kernel* (one output write from the f32 accumulator instead of
three HBM passes), for the other methods as the same unfused op sequence
the pre-engine executor ran — bit-for-bit compatible.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core.direct_conv import dense_conv, direct_sparse_conv
from repro.core.lowering import lowered_sparse_conv
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import (balance_ell_conv, bcsr_conv_from_dense,
                                      ell_from_dense, ell_from_dense_conv,
                                      quantize_values)
from repro.engine.program import (ConcatOp, ConvOp, FCOp, PoolOp, Program,
                                  ReluOp, ResidualAddOp)
from repro.kernels.bsr_conv.ops import bsr_conv, resolve_bsr_schedule
from repro.kernels.sparse_conv.ops import resolve_schedule
from repro.kernels.sparse_conv.ops import sparse_conv as pallas_sparse_conv
from repro.telemetry.fallback import record_fallback
from repro.telemetry.report import ExecutionReport, OpReport

METHODS = ("dense", "lowered", "csr-direct", "pallas", "bsr", "auto")

# Default BCSR tile shape for a direct ``method="bsr"`` call (no tuned plan
# pinning one); the autotuner picks per layer from the block ladder.
DEFAULT_BSR_BLOCK = (8, 128)


@dataclasses.dataclass
class _Decision:
    """One conv op's resolved dispatch knobs — what the plan (or the
    caller) asked for, before the kernel's own feasibility checks.

    Pure Python over plan entries; shared by ``_conv`` (trace time) and
    ``execution_report`` (no execution), so the report can never disagree
    with what the executor dispatches.
    """

    auto: bool                    # method="auto" (plan-driven) call
    pe: Any                       # the PlanEntry consulted (None without)
    method: str                   # method to execute (pre-kernel-checks)
    method_planned: str           # what the plan/caller asked for
    tm: Optional[int]
    te: Optional[int]
    tf: Optional[int]
    pipeline: Optional[bool]
    permute: bool
    fuse: bool
    block: Optional[Tuple[int, int]]
    value_dtype: str              # value-storage dtype the kernel streams
    quantize_in_trace: bool       # f32 bank, narrow plan: quantise in-trace
    engine_reason: Optional[str]  # engine-level fallback (stale bsr plan,
                                  # value-dtype mismatch)
    provenance: str


def init_conv_params(program: Program, rng: np.random.Generator,
                     ) -> Dict[str, Any]:
    """Random pruned weights for every conv of a lowered program.

    Draws in ``conv_table`` (historical spec-walk) order, then one integer
    for the FC weight stream, so the result is bit-identical to the
    pre-engine ``init_cnn``.
    """
    params: Dict[str, Any] = {}
    for l, (c, _, _) in program.conv_table:
        w = (rng.standard_normal((l.out_c, c, l.k, l.k))
             .astype(np.float32) * (2.0 / (c * l.k * l.k)) ** 0.5)
        if l.sparsity > 0:
            w = np.asarray(magnitude_prune(jnp.asarray(w), l.sparsity))
        entry = {"w": jnp.asarray(w), "b": jnp.zeros((l.out_c,), jnp.float32)}
        if l.sparsity > 0:
            entry["ell"] = ell_from_dense_conv(w)
            entry["ell2d"] = ell_from_dense(w.reshape(l.out_c, -1))
        params[l.name] = entry
    params["_fc_rng"] = rng.integers(0, 2**31)
    return params


def _pool(op: PoolOp, x: jax.Array) -> jax.Array:
    if op.kind == "gap":
        return x.mean(axis=(2, 3), keepdims=True)
    init = -jnp.inf if op.kind == "max" else 0.0
    red = jax.lax.max if op.kind == "max" else jax.lax.add
    y = jax.lax.reduce_window(
        x, init, red, (1, 1, op.k, op.k), (1, 1, op.stride, op.stride),
        ((0, 0), (0, 0), (op.pad, op.pad), (op.pad, op.pad)))
    if op.kind == "avg":
        y = y / (op.k * op.k)
    return y


class CnnEngine:
    """Program + params (+ plan) -> cached-jit executor.

    ``engine(x, method=...)`` compiles once per (method, input shape/dtype,
    fuse override) and replays the compiled program afterwards.  ``plan``
    is a ``{layer_name: PlanEntry}`` table from ``repro.tuning``; with
    ``method="auto"`` and no plan bound, a roofline-mode plan is computed
    per batch size on first use.

    ``fuse=None`` (default) fuses the Pallas epilogue in-kernel (and honors
    each plan entry's ``fuse`` flag under ``method="auto"``); ``fuse=False``
    forces the unfused three-pass epilogue — the benchmark baseline.
    Plan entries' ``pipeline`` (double-buffered halo DMA) and ``permute``
    (nnz-balanced bank) flags are honored under ``method="auto"``; plain
    ``method="pallas"`` lets ``ops.sparse_conv`` auto-enable the pipeline
    whenever the second halo buffer fits VMEM.

    ``method="bsr"`` runs the BCSR MXU conv kernel: a plan entry's
    ``(block_m, block_n)`` picks the tile shape (``DEFAULT_BSR_BLOCK`` for
    direct calls); banks not prebuilt by ``apply_plan_to_params`` are
    blocked from the bound dense weights at trace time.  A stale plan entry
    claiming ``bsr`` with no block shape (pre-v5 cache) falls back to the
    dense executor.

    ``strict=True`` runs the pre-flight static verifier at bind time
    (``repro.analysis``): the lowered program is structurally checked and
    every plan-pinned Pallas/BCSR schedule is verified to actually
    dispatch — a configuration that would silently fall back at serving
    time raises :class:`repro.analysis.PreflightError` here instead.
    """

    def __init__(self, program: Program, params: Dict[str, Any],
                 plan: Optional[Dict[str, Any]] = None, *,
                 strict: bool = False):
        self.program = program
        self.params = params
        self.plan = plan
        if strict:
            # Lazy import: repro.analysis imports this module's kernel deps.
            from repro.analysis import PreflightError
            from repro.analysis.checker import preflight
            diags = preflight(program, plan, params)
            errors = [d for d in diags if d.severity == "error"]
            if errors:
                raise PreflightError(errors)
        self.fc_weights = self._bind_fc(program, params)
        self._fns: Dict[Any, Any] = {}
        self._auto_plans: Dict[int, Dict[str, Any]] = {}
        # Trace-built BCSR banks, keyed (layer, block): params are never
        # mutated (their leaf identities fingerprint the engine memo), so
        # banks built for a plan block that differs from the prebuilt one
        # are cached here instead of rebuilt every trace/report.
        self._bcc_cache: Dict[Any, Any] = {}
        # The ExecutionReport of the most recent telemetry-enabled forward.
        self.last_report: Optional[ExecutionReport] = None

    # -- bind -------------------------------------------------------------

    @staticmethod
    def _bind_fc(program: Program, params: Dict[str, Any],
                 ) -> Dict[Any, np.ndarray]:
        """FC weights, created here (not by mutating ``params`` mid-trace).

        Keyed on ``(name, in_f)`` and drawn in program order from the
        ``_fc_rng`` seed — the same stream positions the historical lazy
        creation used on a fresh params dict, so two engines bound at
        different image sizes get identical weights for every FC layer
        whose fan-in agrees, and can never collide when fan-ins differ.
        """
        rng = np.random.default_rng(int(params.get("_fc_rng", 0)))
        out: Dict[Any, np.ndarray] = {}
        for op in program.fc_ops:
            out[(op.name, op.in_f)] = (
                rng.standard_normal((op.in_f, op.out_f))
                .astype(np.float32) * (1.0 / op.in_f) ** 0.5)
        return out

    def _auto_plan(self, batch: int) -> Dict[str, Any]:
        plan = self._auto_plans.get(batch)
        if plan is None:
            from repro.tuning.planner import plan_program  # lazy: avoids cycle
            # Pass the bound params: roofline mode then prices bsr
            # candidates from each layer's *actual* kept-block structure
            # (unstructured banks keep nearly every tile and must not be
            # routed to the MXU path on the block-pruned estimate).
            plan = plan_program(self.program, batch=batch, mode="roofline",
                                params=self.params)
            self._auto_plans[batch] = plan
        return plan

    # -- dispatch decisions ------------------------------------------------

    def _plan_decision(self, op: ConvOp, method: str, plan,
                       fuse_override: Optional[bool]) -> _Decision:
        """Resolve one conv op's dispatch knobs from the plan (or the
        caller's direct method) — the pure-Python half of ``_conv``."""
        auto = method == "auto"
        tm = te = tf = None
        pipeline = None  # ops.sparse_conv auto-picks when the 2nd halo fits
        permute = False
        block = None     # bsr: None = any prebuilt bank (or the default)
        fuse = True if fuse_override is None else fuse_override
        pe = None
        engine_reason = None
        provenance = "direct"
        if auto:
            pe = (plan or {}).get(op.name)
            method = pe.method if pe is not None else "dense"
            provenance = pe.provenance if pe is not None else "default"
            if pe is not None:
                tm, te, tf = pe.tm, pe.te, pe.tf
                pipeline, permute = pe.pipeline, pe.permute
                if fuse_override is None:
                    fuse = pe.fuse
                if method == "bsr":
                    if pe.block_m is None or pe.block_n is None:
                        # Stale plan predating the v5 schema: no block
                        # shape to run — fall back to the dense executor.
                        method = "dense"
                        engine_reason = "stale_plan_no_block"
                    else:
                        block = (pe.block_m, pe.block_n)
        method_planned = pe.method if (auto and pe is not None) else (
            "dense" if auto else method)
        value_dtype = "float32"
        quantize_in_trace = False
        if auto and pe is not None and method in ("pallas", "bsr"):
            # Value-dtype resolution: what the plan pinned vs what the bound
            # bank stores.  Match -> run the bank as-is.  f32 bank + narrow
            # plan (apply_plan_to_params not run) -> quantise in-trace, the
            # same per-channel symmetric construction it would have built
            # host-side.  Any other mismatch — a migrated (f32) entry
            # against an already-quantised bank, or two different narrow
            # dtypes — is a stale plan: the entry was scored for a value
            # stream the params no longer carry, so fall back to dense and
            # say so rather than silently dequantising.
            want = pe.value_dtype
            entry = self.params.get(op.name, {})
            if method == "pallas":
                bank = entry.get("ell_auto", entry.get("ell"))
            else:
                bank = entry.get("bcsr_auto")
                if bank is not None and not (block is None
                                             or bank.block == block):
                    bank = None  # _bcsr_for rebuilds f32 from dense weights
            have = ("float32" if bank is None or bank.scale is None
                    else bank.value_dtype)
            if want == have:
                value_dtype = want
            elif have == "float32":
                value_dtype = want
                quantize_in_trace = True
            else:
                method = "dense"
                engine_reason = "value_dtype_mismatch"
        return _Decision(auto=auto, pe=pe, method=method,
                         method_planned=method_planned, tm=tm, te=te, tf=tf,
                         pipeline=pipeline, permute=permute, fuse=fuse,
                         block=block, value_dtype=value_dtype,
                         quantize_in_trace=quantize_in_trace,
                         engine_reason=engine_reason,
                         provenance=provenance)

    def _bcsr_for(self, op: ConvOp, entry: Dict[str, Any], block):
        """The BCSR bank this op runs: the prebuilt ``bcsr_auto`` when its
        block matches, else one blocked from the bound dense weights —
        built host-side once per (layer, block) and cached on the engine
        (``entry["w"]`` is a concrete bound array, so the conversion is
        trace-safe and baked into the compile)."""
        bcc = entry.get("bcsr_auto")
        if bcc is not None and (block is None or bcc.block == block):
            return bcc
        key = (op.name, block or DEFAULT_BSR_BLOCK)
        bcc = self._bcc_cache.get(key)
        if bcc is None:
            bcc = bcsr_conv_from_dense(np.asarray(entry["w"]),
                                       block=block or DEFAULT_BSR_BLOCK)
            self._bcc_cache[key] = bcc
        return bcc

    # -- execute ----------------------------------------------------------

    def _conv(self, op: ConvOp, x: jax.Array, res: Optional[jax.Array],
              method: str, plan, fuse_override: Optional[bool]) -> jax.Array:
        entry = self.params[op.name]
        d = self._plan_decision(op, method, plan, fuse_override)
        method, fuse = d.method, d.fuse
        tm, te, tf, pipeline = d.tm, d.te, d.tf, d.pipeline
        if d.auto:
            ell = entry.get("ell_auto", entry.get("ell"))
            ell2d = entry.get("ell2d_auto", entry.get("ell2d"))
            if (d.permute and method == "pallas" and ell is not None
                    and ell.perm is None):
                # Plan wants the nnz-balanced bank but the params carry a
                # natural-order one (apply_plan_to_params not run): balance
                # in-trace — pure gathers, jit-safe.
                ell = balance_ell_conv(ell)
            if (d.quantize_in_trace and method == "pallas"
                    and ell is not None):
                # Plan pinned a narrow value dtype but the params carry the
                # f32 bank (apply_plan_to_params not run): quantise
                # in-trace — pure jnp, jit-safe, identical to the
                # host-side construction.
                ell = quantize_values(ell, d.value_dtype)
        else:
            ell, ell2d = entry.get("ell"), entry.get("ell2d")
        if d.engine_reason is not None:
            # Engine-level silent degradation (stale bsr plan): report it
            # like the kernels report theirs — this runs at trace time.
            record_fallback(
                "engine", d.engine_reason, layer=op.name,
                geometry=f"m={op.m} c={op.c} e={op.e} f={op.f}",
                fallback_to="dense")
        bcc = None
        if method == "bsr" and op.sparsity > 0:
            bcc = self._bcsr_for(op, entry, d.block)
            if d.quantize_in_trace and bcc.scale is None:
                bcc = quantize_values(bcc, d.value_dtype)
        b = entry["b"]
        if op.sparsity == 0 or method == "dense":
            y = dense_conv(x, entry["w"], stride=op.stride, padding=op.pad)
        elif method == "lowered":
            y = lowered_sparse_conv(x, ell2d, op.k, op.k,
                                    stride=op.stride, padding=op.pad)
        elif method == "csr-direct":
            y = direct_sparse_conv(x, ell, stride=op.stride, padding=op.pad)
        elif method == "pallas":
            interp = jax.default_backend() != "tpu"
            if fuse:
                return pallas_sparse_conv(
                    x, ell, stride=op.stride, padding=op.pad, tm=tm, te=te,
                    tf=tf, bias=b, fuse_relu=op.fuse_relu, residual=res,
                    pipeline=pipeline, interpret=interp, layer=op.name)
            y = pallas_sparse_conv(x, ell, stride=op.stride, padding=op.pad,
                                   tm=tm, te=te, tf=tf, pipeline=pipeline,
                                   interpret=interp, layer=op.name)
        elif method == "bsr":
            interp = jax.default_backend() != "tpu"
            if fuse:
                return bsr_conv(
                    x, bcc, stride=op.stride, padding=op.pad, te=te, tf=tf,
                    bias=b, fuse_relu=op.fuse_relu, residual=res,
                    interpret=interp, layer=op.name)
            y = bsr_conv(x, bcc, stride=op.stride, padding=op.pad, te=te,
                         tf=tf, interpret=interp, layer=op.name)
        else:
            raise ValueError(method)
        # Unfused epilogue: the exact op sequence of the pre-engine executor.
        y = y + b[None, :, None, None]
        if res is not None:
            y = y + res
        if op.fuse_relu:
            y = jax.nn.relu(y)
        return y

    def _exec_op(self, op, vals: Dict[int, jax.Array], method: str, plan,
                 fuse_override: Optional[bool]) -> jax.Array:
        """Execute one program op against the value table."""
        if isinstance(op, ConvOp):
            res = vals[op.res] if op.res is not None else None
            return self._conv(op, vals[op.src], res, method, plan,
                              fuse_override)
        if isinstance(op, ReluOp):
            return jax.nn.relu(vals[op.src])
        if isinstance(op, PoolOp):
            return _pool(op, vals[op.src])
        if isinstance(op, ConcatOp):
            return jnp.concatenate([vals[s] for s in op.srcs], axis=1)
        if isinstance(op, ResidualAddOp):
            y = vals[op.a] + vals[op.b]
            return jax.nn.relu(y) if op.fuse_relu else y
        if isinstance(op, FCOp):
            flat = vals[op.src].reshape(vals[op.src].shape[0], -1)
            return flat @ self.fc_weights[(op.name, op.in_f)]
        raise TypeError(f"unknown op {op!r}")

    def _execute(self, x: jax.Array, *, method: str, plan,
                 fuse_override: Optional[bool]) -> jax.Array:
        vals: Dict[int, jax.Array] = {0: x}
        for op in self.program.ops:
            vals[op.out] = self._exec_op(op, vals, method, plan,
                                         fuse_override)
        return vals[self.program.out]

    def __call__(self, x: jax.Array, method: str = "dense", *,
                 fuse: Optional[bool] = None,
                 plan_override: Optional[Dict[str, Any]] = None,
                 rung: Optional[str] = None) -> jax.Array:
        """Execute the bound program.

        ``plan_override`` substitutes an alternate plan table for this call
        without rebinding the engine — the degraded-plan resolution the
        serving tier's ladder uses (``repro.serving.robust``): each rung is
        its own persistent plan dict, so each (method, shape, rung plan)
        still compiles exactly once.  ``rung`` is a label recorded on the
        forward's :class:`ExecutionReport` naming the ladder rung executed.
        """
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; one of {METHODS}")
        plan = plan_override if plan_override is not None else self.plan
        if method == "auto" and plan is None:
            plan = self._auto_plan(int(x.shape[0]))
        key = (method, tuple(x.shape), str(x.dtype), fuse, id(plan))
        fn = self._fns.get(key)
        jit_hit = fn is not None
        if fn is None:
            fn = jax.jit(functools.partial(
                self._execute, method=method, plan=plan, fuse_override=fuse))
            self._fns[key] = fn
        if telemetry.is_enabled():
            # Dispatch-time observation: the report is built from the same
            # _plan_decision the trace uses, never from inside the jit.
            self._record_forward(tuple(x.shape), str(x.dtype), method, plan,
                                 fuse, jit_hit, rung=rung)
        return fn(x)

    # -- observability -----------------------------------------------------

    def _record_forward(self, shape, dtype: str, method: str, plan,
                        fuse_override: Optional[bool],
                        jit_hit: bool, rung: Optional[str] = None) -> None:
        report = self._build_report(shape, dtype, method, plan,
                                    fuse_override, jit_hit, rung=rung)
        self.last_report = report
        telemetry.counter("engine.forwards").inc()
        telemetry.counter(
            "engine.jit_hits" if jit_hit else "engine.jit_misses").inc()
        if report.fallback_count:
            telemetry.counter("engine.fallback_ops").inc(
                report.fallback_count)
        report.emit_spans(telemetry.get_tracer())

    def _build_report(self, shape, dtype: str, method: str, plan,
                      fuse_override: Optional[bool],
                      jit_hit: Optional[bool],
                      rung: Optional[str] = None) -> ExecutionReport:
        batch = int(shape[0])
        report = ExecutionReport(
            method=method, batch=batch, in_shape=tuple(shape), dtype=dtype,
            jit_cache_hit=jit_hit, plan_bound=self.plan is not None,
            rung=rung)
        for op in self.program.conv_ops:
            report.ops.append(self._op_report(op, method, plan,
                                              fuse_override, batch=batch,
                                              dtype=dtype))
        return report

    def _op_report(self, op: ConvOp, method: str, plan,
                   fuse_override: Optional[bool], *, batch: int,
                   dtype: str) -> OpReport:
        """One conv op's OpReport: the dispatch decision (including the
        kernels' own feasibility checks, via their ``resolve_*`` probes)
        plus the roofline attribution of the *executed* schedule."""
        # Lazy: repro.tuning imports this module's kernel deps.
        from repro.tuning.measure import candidate_cost
        from repro.tuning.planner import geometry_of_op
        from repro.tuning.space import Candidate

        entry = self.params[op.name]
        d = self._plan_decision(op, method, plan, fuse_override)
        g = geometry_of_op(op, batch=batch, dtype=dtype)
        executed = "dense" if op.sparsity == 0 else d.method
        reason = d.engine_reason
        pad_to = d.pe.pad_to if d.pe is not None else None
        fuse_res = d.fuse and op.res is not None
        tiling: Dict[str, Any] = {}
        if executed == "pallas":
            ell = (entry.get("ell_auto", entry.get("ell")) if d.auto
                   else entry.get("ell"))
            k = ell.k if ell is not None else g.k_est(pad_to or 8)
            sched, kreason = resolve_schedule(
                op.m, op.c, op.e, op.f, k, op.k, op.k, op.stride, tm=d.tm,
                te=d.te, tf=d.tf, fuse_res=fuse_res, pipeline=d.pipeline,
                value_dtype=d.value_dtype)
            if sched is None:
                reason, executed = kreason, "csr-direct"
            else:
                tm, te, tf, pipe = sched
                tiling = {"tm": tm, "te": te, "tf": tf, "pipeline": pipe}
        elif executed == "bsr":
            bcc = self._bcsr_for(op, entry, d.block)
            gbm, kb, bm, bn = bcc.blocks.shape
            itemsize = 2 if dtype in ("bfloat16", "float16") else 4
            sched, kreason = resolve_bsr_schedule(
                op.c, op.e, op.f, op.k, op.k, op.stride, bm, bn, gbm, kb,
                itemsize=itemsize, te=d.te, tf=d.tf, fuse_res=fuse_res,
                value_dtype=d.value_dtype)
            if sched is None:
                reason, executed = kreason, "dense"
            else:
                te, tf = sched
                tiling = {"te": te, "tf": tf, "block_m": bm, "block_n": bn}
        # Attribute cost at the schedule that actually runs — a fallback op
        # is charged for its fallback path, not the method it asked for.
        vdtype = d.value_dtype if executed in ("pallas", "bsr") else "float32"
        cand = Candidate(
            method=executed, tm=tiling.get("tm"), pad_to=pad_to,
            te=tiling.get("te"), tf=tiling.get("tf"),
            fuse=d.fuse if executed in ("pallas", "bsr") else False,
            pipeline=bool(tiling.get("pipeline", False)),
            permute=d.permute if executed == "pallas" else False,
            block_m=tiling.get("block_m"), block_n=tiling.get("block_n"),
            value_dtype=vdtype)
        w = entry.get("w") if executed == "bsr" else None
        cost = candidate_cost(
            g, cand, w_dense=None if w is None else np.asarray(w))
        return OpReport(
            name=op.name, method_planned=d.method_planned,
            method_executed=executed, provenance=d.provenance,
            plan_source=d.pe.source if d.pe is not None else "-",
            fallback_reason=reason, fuse=d.fuse, tiling=tiling,
            sparsity=op.sparsity, value_dtype=vdtype, **cost)

    def execution_report(self, x, method: str = "auto", *,
                         fuse: Optional[bool] = None,
                         plan_override: Optional[Dict[str, Any]] = None,
                         rung: Optional[str] = None) -> ExecutionReport:
        """The ExecutionReport a forward with these arguments would produce,
        built without executing anything.

        ``x`` is the input array or just its shape tuple — dispatch is
        static Python over shapes and plan entries, so the report needs
        neither data nor a compile.  ``jit_cache_hit`` reflects whether the
        corresponding compiled function already exists.  ``plan_override``
        and ``rung`` mirror :meth:`__call__` — the serving ladder probes
        each rung's dispatch health through this before routing traffic at
        it.
        """
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; one of {METHODS}")
        shape = tuple(x.shape) if hasattr(x, "shape") else tuple(x)
        dtype = str(x.dtype) if hasattr(x, "dtype") else "float32"
        plan = plan_override if plan_override is not None else self.plan
        if method == "auto" and plan is None:
            plan = self._auto_plan(int(shape[0]))
        key = (method, shape, dtype, fuse, id(plan))
        return self._build_report(shape, dtype, method, plan, fuse,
                                  jit_hit=key in self._fns, rung=rung)

    def forward_timed(self, x: jax.Array, method: str = "auto", *,
                      fuse: Optional[bool] = None) -> jax.Array:
        """Opt-in timed mode: execute op-by-op with ``block_until_ready``
        at every op boundary, recording real per-op wall spans on the
        tracer's ``wall`` lane and (when available) wrapping each op in a
        ``jax.profiler`` named scope so XLA profiles map back to layer
        names.

        The boundaries defeat whole-program fusion and force a host sync
        per op, so this is a profiling tool, not a serving path — expect
        it to be slower than ``engine(x, ...)``.  Calling it is the opt-in;
        it records regardless of the global telemetry flag and leaves the
        measured report on ``self.last_report`` (``wall_s`` filled for
        every conv).
        """
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; one of {METHODS}")
        plan = self.plan
        if method == "auto" and plan is None:
            plan = self._auto_plan(int(x.shape[0]))
        report = self._build_report(tuple(x.shape), str(x.dtype), method,
                                    plan, fuse, jit_hit=None)
        report.timed = True
        tracer = telemetry.get_tracer()
        annotate = getattr(jax.profiler, "TraceAnnotation", None)
        walls: Dict[str, float] = {}
        vals: Dict[int, jax.Array] = {0: x}
        for op in self.program.ops:
            name = getattr(op, "name", None) or f"{type(op).__name__}:{op.out}"
            scope = (annotate(name) if annotate is not None
                     else contextlib.nullcontext())
            t0 = time.perf_counter()
            with scope:
                vals[op.out] = self._exec_op(op, vals, method, plan, fuse)
                jax.block_until_ready(vals[op.out])
            dt = time.perf_counter() - t0
            tracer.complete(name, start_s=t0, dur_s=dt, cat="op.timed",
                            tid=telemetry.TID_WALL,
                            args={"kind": type(op).__name__})
            if isinstance(op, ConvOp):
                walls[op.name] = dt
        for o in report.ops:
            o.wall_s = walls.get(o.name)
        self.last_report = report
        return vals[self.program.out]
