"""CnnEngine: bind params + a tuned plan to a lowered program and execute.

The compile-once executor: a :class:`~repro.engine.program.Program` (from
``lower()``) plus a parameter dict plus an optional tuned plan, executed
through a cached ``jax.jit`` per (method, input geometry, fuse override).
Nothing here walks the nested spec and nothing mutates ``params`` inside a
trace — FC weights are created once at *bind* time from each ``FCOp``'s
statically-resolved fan-in.

Conv epilogues (``bias → ReLU`` and bottleneck ``bias → +shortcut → ReLU``)
were fused into ``ConvOp`` at lowering time; for the Pallas method they are
executed *in-kernel* (one output write from the f32 accumulator instead of
three HBM passes), for the other methods as the same unfused op sequence
the pre-engine executor ran — bit-for-bit compatible.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.direct_conv import dense_conv, direct_sparse_conv
from repro.core.lowering import lowered_sparse_conv
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import (balance_ell_conv, bcsr_conv_from_dense,
                                      ell_from_dense, ell_from_dense_conv)
from repro.engine.program import (ConcatOp, ConvOp, FCOp, PoolOp, Program,
                                  ReluOp, ResidualAddOp)
from repro.kernels.bsr_conv.ops import bsr_conv
from repro.kernels.sparse_conv.ops import sparse_conv as pallas_sparse_conv

METHODS = ("dense", "lowered", "csr-direct", "pallas", "bsr", "auto")

# Default BCSR tile shape for a direct ``method="bsr"`` call (no tuned plan
# pinning one); the autotuner picks per layer from the block ladder.
DEFAULT_BSR_BLOCK = (8, 128)


def init_conv_params(program: Program, rng: np.random.Generator,
                     ) -> Dict[str, Any]:
    """Random pruned weights for every conv of a lowered program.

    Draws in ``conv_table`` (historical spec-walk) order, then one integer
    for the FC weight stream, so the result is bit-identical to the
    pre-engine ``init_cnn``.
    """
    params: Dict[str, Any] = {}
    for l, (c, _, _) in program.conv_table:
        w = (rng.standard_normal((l.out_c, c, l.k, l.k))
             .astype(np.float32) * (2.0 / (c * l.k * l.k)) ** 0.5)
        if l.sparsity > 0:
            w = np.asarray(magnitude_prune(jnp.asarray(w), l.sparsity))
        entry = {"w": jnp.asarray(w), "b": jnp.zeros((l.out_c,), jnp.float32)}
        if l.sparsity > 0:
            entry["ell"] = ell_from_dense_conv(w)
            entry["ell2d"] = ell_from_dense(w.reshape(l.out_c, -1))
        params[l.name] = entry
    params["_fc_rng"] = rng.integers(0, 2**31)
    return params


def _pool(op: PoolOp, x: jax.Array) -> jax.Array:
    if op.kind == "gap":
        return x.mean(axis=(2, 3), keepdims=True)
    init = -jnp.inf if op.kind == "max" else 0.0
    red = jax.lax.max if op.kind == "max" else jax.lax.add
    y = jax.lax.reduce_window(
        x, init, red, (1, 1, op.k, op.k), (1, 1, op.stride, op.stride),
        ((0, 0), (0, 0), (op.pad, op.pad), (op.pad, op.pad)))
    if op.kind == "avg":
        y = y / (op.k * op.k)
    return y


class CnnEngine:
    """Program + params (+ plan) -> cached-jit executor.

    ``engine(x, method=...)`` compiles once per (method, input shape/dtype,
    fuse override) and replays the compiled program afterwards.  ``plan``
    is a ``{layer_name: PlanEntry}`` table from ``repro.tuning``; with
    ``method="auto"`` and no plan bound, a roofline-mode plan is computed
    per batch size on first use.

    ``fuse=None`` (default) fuses the Pallas epilogue in-kernel (and honors
    each plan entry's ``fuse`` flag under ``method="auto"``); ``fuse=False``
    forces the unfused three-pass epilogue — the benchmark baseline.
    Plan entries' ``pipeline`` (double-buffered halo DMA) and ``permute``
    (nnz-balanced bank) flags are honored under ``method="auto"``; plain
    ``method="pallas"`` lets ``ops.sparse_conv`` auto-enable the pipeline
    whenever the second halo buffer fits VMEM.

    ``method="bsr"`` runs the BCSR MXU conv kernel: a plan entry's
    ``(block_m, block_n)`` picks the tile shape (``DEFAULT_BSR_BLOCK`` for
    direct calls); banks not prebuilt by ``apply_plan_to_params`` are
    blocked from the bound dense weights at trace time.  A stale plan entry
    claiming ``bsr`` with no block shape (pre-v5 cache) falls back to the
    dense executor.
    """

    def __init__(self, program: Program, params: Dict[str, Any],
                 plan: Optional[Dict[str, Any]] = None):
        self.program = program
        self.params = params
        self.plan = plan
        self.fc_weights = self._bind_fc(program, params)
        self._fns: Dict[Any, Any] = {}
        self._auto_plans: Dict[int, Dict[str, Any]] = {}

    # -- bind -------------------------------------------------------------

    @staticmethod
    def _bind_fc(program: Program, params: Dict[str, Any],
                 ) -> Dict[Any, np.ndarray]:
        """FC weights, created here (not by mutating ``params`` mid-trace).

        Keyed on ``(name, in_f)`` and drawn in program order from the
        ``_fc_rng`` seed — the same stream positions the historical lazy
        creation used on a fresh params dict, so two engines bound at
        different image sizes get identical weights for every FC layer
        whose fan-in agrees, and can never collide when fan-ins differ.
        """
        rng = np.random.default_rng(int(params.get("_fc_rng", 0)))
        out: Dict[Any, np.ndarray] = {}
        for op in program.fc_ops:
            out[(op.name, op.in_f)] = (
                rng.standard_normal((op.in_f, op.out_f))
                .astype(np.float32) * (1.0 / op.in_f) ** 0.5)
        return out

    def _auto_plan(self, batch: int) -> Dict[str, Any]:
        plan = self._auto_plans.get(batch)
        if plan is None:
            from repro.tuning.planner import plan_program  # lazy: avoids cycle
            # Pass the bound params: roofline mode then prices bsr
            # candidates from each layer's *actual* kept-block structure
            # (unstructured banks keep nearly every tile and must not be
            # routed to the MXU path on the block-pruned estimate).
            plan = plan_program(self.program, batch=batch, mode="roofline",
                                params=self.params)
            self._auto_plans[batch] = plan
        return plan

    # -- execute ----------------------------------------------------------

    def _conv(self, op: ConvOp, x: jax.Array, res: Optional[jax.Array],
              method: str, plan, fuse_override: Optional[bool]) -> jax.Array:
        entry = self.params[op.name]
        tm = te = tf = None
        pipeline = None  # ops.sparse_conv auto-picks when the 2nd halo fits
        permute = False
        block = None     # bsr: None = any prebuilt bank (or the default)
        bcc = entry.get("bcsr_auto")
        fuse = True if fuse_override is None else fuse_override
        if method == "auto":
            pe = (plan or {}).get(op.name)
            method = pe.method if pe is not None else "dense"
            if pe is not None:
                tm, te, tf = pe.tm, pe.te, pe.tf
                pipeline, permute = pe.pipeline, pe.permute
                if fuse_override is None:
                    fuse = pe.fuse
                if method == "bsr":
                    if pe.block_m is None or pe.block_n is None:
                        # Stale plan predating the v5 schema: no block
                        # shape to run — fall back to the dense executor.
                        method = "dense"
                    else:
                        block = (pe.block_m, pe.block_n)
            ell = entry.get("ell_auto", entry.get("ell"))
            ell2d = entry.get("ell2d_auto", entry.get("ell2d"))
            if (permute and method == "pallas" and ell is not None
                    and ell.perm is None):
                # Plan wants the nnz-balanced bank but the params carry a
                # natural-order one (apply_plan_to_params not run): balance
                # in-trace — pure gathers, jit-safe.
                ell = balance_ell_conv(ell)
        else:
            ell, ell2d = entry.get("ell"), entry.get("ell2d")
        if method == "bsr" and op.sparsity > 0 and (
                bcc is None or (block is not None and bcc.block != block)):
            # Plan block differs from the prebuilt bank (or
            # apply_plan_to_params wasn't run): block the dense weights at
            # trace time — ``entry["w"]`` is a concrete bound array, so the
            # host-side conversion runs once per compile and is baked in.
            bcc = bcsr_conv_from_dense(np.asarray(entry["w"]),
                                       block=block or DEFAULT_BSR_BLOCK)
        b = entry["b"]
        if op.sparsity == 0 or method == "dense":
            y = dense_conv(x, entry["w"], stride=op.stride, padding=op.pad)
        elif method == "lowered":
            y = lowered_sparse_conv(x, ell2d, op.k, op.k,
                                    stride=op.stride, padding=op.pad)
        elif method == "csr-direct":
            y = direct_sparse_conv(x, ell, stride=op.stride, padding=op.pad)
        elif method == "pallas":
            interp = jax.default_backend() != "tpu"
            if fuse:
                return pallas_sparse_conv(
                    x, ell, stride=op.stride, padding=op.pad, tm=tm, te=te,
                    tf=tf, bias=b, fuse_relu=op.fuse_relu, residual=res,
                    pipeline=pipeline, interpret=interp)
            y = pallas_sparse_conv(x, ell, stride=op.stride, padding=op.pad,
                                   tm=tm, te=te, tf=tf, pipeline=pipeline,
                                   interpret=interp)
        elif method == "bsr":
            interp = jax.default_backend() != "tpu"
            if fuse:
                return bsr_conv(
                    x, bcc, stride=op.stride, padding=op.pad, te=te, tf=tf,
                    bias=b, fuse_relu=op.fuse_relu, residual=res,
                    interpret=interp)
            y = bsr_conv(x, bcc, stride=op.stride, padding=op.pad, te=te,
                         tf=tf, interpret=interp)
        else:
            raise ValueError(method)
        # Unfused epilogue: the exact op sequence of the pre-engine executor.
        y = y + b[None, :, None, None]
        if res is not None:
            y = y + res
        if op.fuse_relu:
            y = jax.nn.relu(y)
        return y

    def _execute(self, x: jax.Array, *, method: str, plan,
                 fuse_override: Optional[bool]) -> jax.Array:
        vals: Dict[int, jax.Array] = {0: x}
        for op in self.program.ops:
            if isinstance(op, ConvOp):
                res = vals[op.res] if op.res is not None else None
                vals[op.out] = self._conv(op, vals[op.src], res, method, plan,
                                          fuse_override)
            elif isinstance(op, ReluOp):
                vals[op.out] = jax.nn.relu(vals[op.src])
            elif isinstance(op, PoolOp):
                vals[op.out] = _pool(op, vals[op.src])
            elif isinstance(op, ConcatOp):
                vals[op.out] = jnp.concatenate([vals[s] for s in op.srcs],
                                               axis=1)
            elif isinstance(op, ResidualAddOp):
                y = vals[op.a] + vals[op.b]
                vals[op.out] = jax.nn.relu(y) if op.fuse_relu else y
            elif isinstance(op, FCOp):
                flat = vals[op.src].reshape(vals[op.src].shape[0], -1)
                vals[op.out] = flat @ self.fc_weights[(op.name, op.in_f)]
            else:
                raise TypeError(f"unknown op {op!r}")
        return vals[self.program.out]

    def __call__(self, x: jax.Array, method: str = "dense", *,
                 fuse: Optional[bool] = None) -> jax.Array:
        if method not in METHODS:
            raise ValueError(f"unknown method {method!r}; one of {METHODS}")
        plan = self.plan
        if method == "auto" and plan is None:
            plan = self._auto_plan(int(x.shape[0]))
        key = (method, tuple(x.shape), str(x.dtype), fuse, id(plan))
        fn = self._fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(
                self._execute, method=method, plan=plan, fuse_override=fuse))
            self._fns[key] = fn
        return fn(x)
