"""Compile-once CNN graph engine.

``lower(net, in_shape)`` walks a nested layer spec exactly once and emits a
flat, typed op program with all geometries resolved statically and conv
epilogues (bias/ReLU/bottleneck shortcut) fused at lowering time;
``CnnEngine`` binds params + a tuned plan to that program and executes via
a cached ``jax.jit`` per (method, geometry).

  spec     -- the layer-spec vocabulary (Conv/Pool/FC/Concat/Residual/Relu)
  program  -- the op set (ConvOp/PoolOp/FCOp/ConcatOp/ResidualAddOp/ReluOp)
  lower    -- the single spec walker (replaces the four historical ones)
  engine   -- CnnEngine + bind-time parameter init
"""
from repro.engine.engine import CnnEngine, METHODS, init_conv_params
from repro.engine.lower import lower
from repro.engine.program import (ConcatOp, ConvOp, FCOp, PoolOp, Program,
                                  ReluOp, ResidualAddOp)
from repro.engine.spec import FC, Concat, Conv, Pool, Relu, Residual

__all__ = [
    "CnnEngine", "Concat", "ConcatOp", "Conv", "ConvOp", "FC", "FCOp",
    "METHODS", "Pool", "PoolOp", "Program", "Relu", "ReluOp", "Residual",
    "ResidualAddOp", "init_conv_params", "lower",
]
