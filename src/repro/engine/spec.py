"""Layer-spec vocabulary for the paper's CNN benchmark models.

These frozen dataclasses are the *source language* of the compile-once
engine: ``repro.models.cnn`` builds AlexNet/GoogLeNet/ResNet-50 tables out
of them, and ``repro.engine.lower`` is the only code that ever walks a
nested spec — everything downstream (init, forward, shape tables, the
autotuner) consumes the flat lowered program instead.

They live here (not in ``models/cnn.py``) so the engine does not import the
model zoo; ``models/cnn.py`` re-exports them under their historical names.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Conv:
    name: str
    out_c: int
    k: int
    stride: int = 1
    pad: int = 0
    sparsity: float = 0.85   # 0.0 => layer kept dense (runs dense always)


@dataclasses.dataclass(frozen=True)
class Pool:
    kind: str                # max | avg | gap
    k: int = 3
    stride: int = 2
    pad: int = 0


@dataclasses.dataclass(frozen=True)
class FC:
    name: str
    out_f: int
    sparsity: float = 0.9


@dataclasses.dataclass(frozen=True)
class Concat:
    """Inception module: parallel branches concatenated on channels."""
    branches: Tuple[Tuple[Any, ...], ...]


@dataclasses.dataclass(frozen=True)
class Residual:
    """ResNet bottleneck: body branch + (optional projection) shortcut."""
    body: Tuple[Any, ...]
    proj: Optional[Conv] = None


@dataclasses.dataclass(frozen=True)
class Relu:
    pass
