"""The single lowering pass: nested layer spec -> flat typed op program.

This replaces the four historical spec walkers (``init_cnn.walk``,
``cnn_forward.walk``, ``conv_layer_shapes.walk``, and the planner's network
walk): the spec is traversed exactly once here, with every geometry resolved
statically, and everything else — parameter init, execution, shape tables,
autotuning — consumes the resulting :class:`~repro.engine.program.Program`.

Epilogue fusion happens at lowering time (the offline-compile step of
Yao et al., arXiv:1811.00206):

* ``Conv → ReLU``                  -> one ``ConvOp(fuse_relu=True)``
* bottleneck ``body[-1] is Conv``  -> the shortcut (projection conv or
  identity) is emitted first and the tail conv becomes
  ``ConvOp(res=<shortcut id>, fuse_relu=<trailing ReLU>)`` — the
  ``Conv → bias → +shortcut → ReLU`` chain the Pallas kernel executes as a
  single output write from the f32 accumulator (Park et al.,
  arXiv:1608.01409).

The ``conv_table`` keeps the historical spec-walk order (Residual: body
convs then projection) so parameter init draws RNG values in the exact
sequence the pre-engine ``init_cnn`` did.
"""
from __future__ import annotations

import itertools
from typing import Any, List, Sequence, Tuple

from repro.core.direct_conv import out_spatial
from repro.engine import spec
from repro.engine.program import (ConcatOp, ConvOp, FCOp, PoolOp, Program,
                                  ReluOp, ResidualAddOp)


def lower(net: Sequence[Any], in_shape: Tuple[int, int, int]) -> Program:
    """Walk ``net`` once and emit a flat program.

    Args:
      net:      nested layer spec (``repro.engine.spec`` dataclasses).
      in_shape: static input geometry ``(C, H, W)`` (batch stays dynamic).
    """
    c0, h0, w0 = (int(d) for d in in_shape)
    ops: List[Any] = []
    table: List[Tuple[spec.Conv, Tuple[int, int, int]]] = []
    ids = itertools.count(1)

    def emit_conv(l: spec.Conv, src: int, c: int, h: int, w: int, *,
                  res=None, fuse_relu: bool = False, defer_table: bool = False):
        e, f = out_spatial(h, w, l.k, l.k, l.stride, l.pad)
        if e <= 0 or f <= 0:
            raise ValueError(
                f"conv {l.name!r}: input {h}x{w} collapses to {e}x{f} "
                f"(k={l.k}, stride={l.stride}, pad={l.pad}) — image too small "
                "for this network")
        op = ConvOp(name=l.name, src=src, out=next(ids), c=c, h=h, w=w,
                    m=l.out_c, k=l.k, stride=l.stride, pad=l.pad,
                    sparsity=l.sparsity, e=e, f=f, fuse_relu=fuse_relu,
                    res=res)
        ops.append(op)
        entry = (l, (c, h, w))
        if not defer_table:
            table.append(entry)
        return op, entry

    def walk(layers, src: int, c: int, h: int, w: int):
        seq = list(layers)
        i = 0
        while i < len(seq):
            l = seq[i]
            nxt = seq[i + 1] if i + 1 < len(seq) else None
            if isinstance(l, spec.Conv):
                fuse = isinstance(nxt, spec.Relu)
                op, _ = emit_conv(l, src, c, h, w, fuse_relu=fuse)
                src, c, h, w = op.out, op.m, op.e, op.f
                if fuse:
                    i += 1
            elif isinstance(l, spec.Relu):
                op = ReluOp(src=src, out=next(ids))
                ops.append(op)
                src = op.out
            elif isinstance(l, spec.Pool):
                if l.kind == "gap":
                    e = f = 1
                else:
                    e, f = out_spatial(h, w, l.k, l.k, l.stride, l.pad)
                    if e <= 0 or f <= 0:
                        raise ValueError(
                            f"pool({l.kind}): input {h}x{w} collapses to "
                            f"{e}x{f} — image too small for this network")
                op = PoolOp(kind=l.kind, k=l.k, stride=l.stride, pad=l.pad,
                            src=src, out=next(ids), e=e, f=f)
                ops.append(op)
                src, h, w = op.out, e, f
            elif isinstance(l, spec.Concat):
                outs, c_sum = [], 0
                bh, bw = h, w
                for br in l.branches:
                    s2, c2, bh, bw = walk(br, src, c, h, w)
                    outs.append(s2)
                    c_sum += c2
                op = ConcatOp(srcs=tuple(outs), out=next(ids))
                ops.append(op)
                src, c, h, w = op.out, c_sum, bh, bw
            elif isinstance(l, spec.Residual):
                fuse = isinstance(nxt, spec.Relu)
                body = list(l.body)
                if body and isinstance(body[-1], spec.Conv):
                    # Fusable tail: shortcut first, then the tail conv with
                    # the whole +shortcut→ReLU epilogue attached.
                    bsrc, bc, bh, bw = walk(body[:-1], src, c, h, w)
                    pentry = None
                    if l.proj is not None:
                        pop, pentry = emit_conv(l.proj, src, c, h, w,
                                                defer_table=True)
                        sc = pop.out
                    else:
                        sc = src
                    lop, _ = emit_conv(body[-1], bsrc, bc, bh, bw, res=sc,
                                       fuse_relu=fuse)
                    if pentry is not None:
                        table.append(pentry)  # spec order: body, then proj
                    src, c, h, w = lop.out, lop.m, lop.e, lop.f
                else:
                    bsrc, bc, bh, bw = walk(body, src, c, h, w)
                    if l.proj is not None:
                        pop, _ = emit_conv(l.proj, src, c, h, w)
                        sc = pop.out
                    else:
                        sc = src
                    op = ResidualAddOp(a=bsrc, b=sc, out=next(ids),
                                       fuse_relu=fuse)
                    ops.append(op)
                    src, c, h, w = op.out, bc, bh, bw
                if fuse:
                    i += 1
            elif isinstance(l, spec.FC):
                op = FCOp(name=l.name, src=src, out=next(ids),
                          in_f=c * h * w, out_f=l.out_f)
                ops.append(op)
                src, c, h, w = op.out, l.out_f, 1, 1
            else:
                raise TypeError(f"unknown layer spec {l!r}")
            i += 1
        return src, c, h, w

    out, _, _, _ = walk(net, 0, c0, h0, w0)
    return Program(ops=tuple(ops), out=out, in_shape=(c0, h0, w0),
                   conv_table=tuple(table))
