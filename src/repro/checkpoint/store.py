"""Sharded checkpointing: the fault-tolerance substrate.

Layout (per step):
    <dir>/step_000123/
        host_000.npz          one shard file per host (its addressable data)
        ...
        MANIFEST.json         tree structure + per-leaf shape/dtype + hosts
        COMMIT                written LAST; a step without COMMIT is ignored

Properties needed at 1000+ nodes:
  * each host writes only its own addressable shards (no cross-host traffic);
  * atomic commit marker -> a crash mid-write can never corrupt restore
    (restart resumes from the latest COMMITted step);
  * restore is *elastic*: the manifest stores global shapes, restore reads
    whichever shard files exist and re-shards onto the CURRENT mesh, so a
    checkpoint taken on 512 chips restarts on 256 (or vice versa);
  * async: ``CheckpointManager.save_async`` snapshots to host RAM inside the
    step boundary and writes to disk on a background thread, overlapping the
    next steps' compute;
  * keep-k garbage collection.
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save_state(state: Any, directory: str, step: int, *, host_id: int = 0,
               n_hosts: int = 1) -> pathlib.Path:
    """Write this host's shard of ``state`` for ``step`` and commit."""
    d = pathlib.Path(directory) / f"step_{step:06d}"
    d.mkdir(parents=True, exist_ok=True)
    keys, leaves, _ = _flatten_with_paths(state)
    arrays: Dict[str, np.ndarray] = {}
    meta: Dict[str, Dict] = {}
    for key, leaf in zip(keys, leaves):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jax.numpy.bfloat16:
            arrays[key] = arr.view(np.uint16)
            meta[key] = {"shape": list(arr.shape), "dtype": "bfloat16"}
        else:
            arrays[key] = arr
            meta[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(d / f"host_{host_id:03d}.npz", **arrays)
    if host_id == 0:
        (d / "MANIFEST.json").write_text(json.dumps(
            {"step": step, "n_hosts": n_hosts, "leaves": meta}))
        (d / "COMMIT").write_text("ok")
    return d


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = []
    for p in d.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "COMMIT").exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_state(like: Any, directory: str, step: int, *,
                  shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs), optionally placing leaves with ``shardings``
    (elastic re-mesh: any source mesh -> any target mesh)."""
    d = pathlib.Path(directory) / f"step_{step:06d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    data: Dict[str, np.ndarray] = {}
    for f in sorted(d.glob("host_*.npz")):
        with np.load(f) as z:
            for k in z.files:
                data[k] = z[k]
    keys, leaves, treedef = _flatten_with_paths(like)
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(leaves))
    out: List[Any] = []
    for key, leaf, sh in zip(keys, leaves, sh_leaves):
        arr = data[key]
        if manifest["leaves"][key]["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async save + keep-k GC + auto-resume."""

    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: Optional[threading.Thread] = None

    def save_async(self, state: Any, step: int) -> None:
        self.wait()
        # Snapshot to host RAM synchronously (consistent cut), write async.
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _write():
            save_state(snapshot, str(self.directory), step,
                       host_id=self.host_id, n_hosts=self.n_hosts)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if self.host_id != 0:
            return
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.directory.iterdir()
            if re.fullmatch(r"step_\d+", p.name) and (p / "COMMIT").exists())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.directory / f"step_{s:06d}", ignore_errors=True)

    def restore_latest(self, like: Any, *, shardings: Any = None):
        step = latest_step(str(self.directory))
        if step is None:
            return None, None
        return restore_state(like, str(self.directory), step,
                             shardings=shardings), step
