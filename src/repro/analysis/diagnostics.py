"""Diagnostic vocabulary for the pre-flight static verifier.

Every rule pack (schedule, plan-cache, program, AST lints) reports findings
as :class:`Diagnostic` records: a stable dotted rule id, a severity, where
the finding anchors (net / layer / location), and a human message.  The
records are machine-readable (``to_dict``) so the CLI's ``--json`` mode and
CI can consume them without parsing prose.

``REASON_RULES`` is the contract between the verifier and the runtime
fallback telemetry: every reason code a kernel or the engine can report
through ``repro.telemetry.fallback`` has exactly one static rule that would
have caught it pre-flight.  A test cross-checks the mapping against
``telemetry.fallback.REASONS`` so a new runtime fallback cannot ship
without its static counterpart.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

SEVERITIES = ("error", "warning", "info")

# Runtime fallback reason code -> the static rule that catches it pre-flight.
REASON_RULES = {
    "smem_infeasible": "sched.smem_budget",
    "no_feasible_tiling": "sched.vmem_tiling",
    "nondividing_tm": "sched.nondividing_tm",
    "stale_plan_no_block": "plan.stale_bsr_no_block",
    "value_dtype_mismatch": "sched.value_dtype_mismatch",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One static finding: rule id + severity + anchor + message."""

    rule: str
    severity: str
    message: str
    net: Optional[str] = None
    layer: Optional[str] = None
    location: Optional[str] = None  # file path, cache key, or op index

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity {self.severity!r} not one of {SEVERITIES}"
            )

    def to_dict(self) -> Dict[str, Any]:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "message": self.message,
            "net": self.net,
            "layer": self.layer,
            "location": self.location,
        }

    def format(self) -> str:
        anchor = " ".join(
            f"{k}={v}"
            for k, v in (
                ("net", self.net),
                ("layer", self.layer),
                ("at", self.location),
            )
            if v
        )
        head = f"{self.severity:<7} {self.rule}"
        return f"{head} [{anchor}] {self.message}" if anchor else (
            f"{head} {self.message}"
        )


@dataclasses.dataclass
class Report:
    """The verifier's output: all diagnostics plus what was checked."""

    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    checked: List[str] = dataclasses.field(default_factory=list)

    def extend(self, diags: List[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def by_severity(self, severity: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == severity]

    @property
    def errors(self) -> List[Diagnostic]:
        return self.by_severity("error")

    @property
    def warnings(self) -> List[Diagnostic]:
        return self.by_severity("warning")

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_dict(self) -> Dict[str, Any]:
        counts = {s: len(self.by_severity(s)) for s in SEVERITIES}
        return {
            "ok": self.ok,
            "counts": counts,
            "checked": list(self.checked),
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def format_human(self) -> str:
        lines = []
        order = {s: i for i, s in enumerate(SEVERITIES)}
        for d in sorted(
            self.diagnostics, key=lambda d: (order[d.severity], d.rule)
        ):
            lines.append(d.format())
        counts = ", ".join(
            f"{len(self.by_severity(s))} {s}(s)" for s in SEVERITIES
        )
        lines.append(f"checked: {', '.join(self.checked) or '(nothing)'}")
        lines.append(f"result: {'OK' if self.ok else 'FAIL'} ({counts})")
        return "\n".join(lines)


class PreflightError(RuntimeError):
    """Strict-mode bind failed: the static verifier found errors."""

    def __init__(self, diagnostics: List[Diagnostic]):
        self.diagnostics = [d for d in diagnostics if d.severity == "error"]
        lines = [f"pre-flight verification failed "
                 f"({len(self.diagnostics)} error(s)):"]
        lines += [f"  {d.format()}" for d in self.diagnostics]
        super().__init__("\n".join(lines))
