"""Pre-flight checker: orchestrate every rule pack over nets and caches.

``run_check`` is what the CLI and CI call: for each requested network it
lowers the spec, runs the program rules, resolves the plan cache (when
given) into the ``{layer_name: PlanEntry}`` table the engine would bind,
and schedule-verifies every conv op; plan-cache files are additionally
audited standalone (every entry, whether or not a net maps to it); the
kernel sources get the AST lints.

``preflight`` is the engine's strict-mode hook: verify one bound
(program, plan, params) triple and return the diagnostics —
``CnnEngine(..., strict=True)`` raises :class:`PreflightError` on errors.
"""

from __future__ import annotations

import glob
import os
import warnings
from typing import Any, Dict, List, Optional, Sequence

from repro.analysis import (
    ast_lints,
    plan_rules,
    program_rules,
    schedule_rules,
)
from repro.analysis.diagnostics import Diagnostic, Report

DEFAULT_NETS = ("alexnet", "googlenet", "resnet50")

# Rule catalogue across every pack: id -> (default severity, one-liner).
ALL_RULES = {}
for _pack in (schedule_rules, plan_rules, program_rules, ast_lints):
    ALL_RULES.update(_pack.RULES)


def _repo_root() -> str:
    # src/repro/analysis/checker.py -> repo root is four levels up.
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def default_plan_path(net: str) -> Optional[str]:
    """The shipped default plan for a net (``plans/<net>.json``), if any."""
    path = os.path.join(_repo_root(), "plans", f"{net}.json")
    return path if os.path.exists(path) else None


def default_kernel_paths() -> List[str]:
    """Every Python source under ``src/repro/kernels`` (the lints skip
    files with no kernel bodies)."""
    base = os.path.join(_repo_root(), "src", "repro", "kernels")
    return sorted(glob.glob(os.path.join(base, "**", "*.py"), recursive=True))


def resolve_plan(
    program, cache_path: str, *, batch: int, dtype: str, backend: str
) -> Dict[str, Any]:
    """The ``{layer_name: PlanEntry}`` table this net would bind from a
    cache file — the same key lookup ``tuning.planner.plan_program`` does,
    minus the scoring (unmatched layers stay unplanned)."""
    from repro.tuning.cache import PlanCache, PlanCacheWarning, layer_key
    from repro.tuning.planner import geometry_of_op

    cache = PlanCache()
    with warnings.catch_warnings():
        # File-level problems are reported by plan_rules.check_plan_file;
        # here we only want whatever entries are salvageable.
        warnings.simplefilter("ignore", PlanCacheWarning)
        if os.path.exists(cache_path):
            cache.load(cache_path)
    plan: Dict[str, Any] = {}
    for op in program.conv_ops:
        g = geometry_of_op(op, batch=batch, dtype=dtype)
        entry = cache.get(layer_key(g, backend))
        if entry is not None:
            plan[op.name] = entry
    return plan


def check_network(
    net: str,
    *,
    plan_cache: Optional[str] = None,
    batch: int = 1,
    image: int = 224,
    dtype: str = "float32",
    backend: str = "cpu",
) -> List[Diagnostic]:
    """Program + schedule rules for one named network."""
    from repro.engine import lower
    from repro.models import cnn

    if net not in cnn.NETWORKS:
        return [
            Diagnostic(
                rule="prog.out_undefined",
                severity="error",
                message=(
                    f"unknown network {net!r}; one of "
                    f"{sorted(cnn.NETWORKS)}"
                ),
                net=net,
            )
        ]
    program = lower(cnn.NETWORKS[net](), (3, image, image))
    out = program_rules.check_program(program, net=net)
    plan = None
    if plan_cache:
        plan = resolve_plan(
            program, plan_cache, batch=batch, dtype=dtype, backend=backend
        )
    out += schedule_rules.check_network(
        program, plan, net=net, batch=batch, dtype=dtype, backend=backend
    )
    return out


def run_check(
    nets: Optional[Sequence[str]] = None,
    plan_caches: Optional[Sequence[str]] = None,
    *,
    batch: int = 1,
    image: int = 224,
    dtype: str = "float32",
    backend: str = "cpu",
    lint_paths: Optional[Sequence[str]] = None,
    lints: bool = True,
) -> Report:
    """The full pre-flight sweep; what ``python -m repro.analysis check``
    runs.

    ``plan_caches=None`` audits each net's shipped default plan
    (``plans/<net>.json``) when present; pass an explicit list to audit
    specific files (each is both audited standalone and resolved against
    every requested net).
    """
    report = Report()
    nets = list(nets) if nets else list(DEFAULT_NETS)
    explicit_caches = plan_caches is not None
    cache_list = list(plan_caches) if explicit_caches else []
    audited = set()
    for net in nets:
        if explicit_caches:
            net_caches = cache_list or [None]
        else:
            net_caches = [default_plan_path(net)]
        for cache_path in net_caches:
            if cache_path and cache_path not in audited:
                audited.add(cache_path)
                report.extend(plan_rules.check_plan_file(cache_path))
                report.checked.append(f"plan:{os.path.basename(cache_path)}")
            report.extend(
                check_network(
                    net,
                    plan_cache=cache_path,
                    batch=batch,
                    image=image,
                    dtype=dtype,
                    backend=backend,
                )
            )
        report.checked.append(f"net:{net}")
    if lints:
        paths = list(lint_paths) if lint_paths else default_kernel_paths()
        report.extend(ast_lints.check_paths(paths))
        report.checked.append(f"lint:{len(paths)} kernel file(s)")
    return report


def preflight(
    program,
    plan: Optional[Dict[str, Any]],
    params: Optional[Dict[str, Any]] = None,
    *,
    batch: int = 1,
    dtype: str = "float32",
    backend: Optional[str] = None,
) -> List[Diagnostic]:
    """Verify one bound (program, plan, params) triple — the engine's
    strict-mode hook.  Pure Python over shapes and plan entries; returns
    the diagnostics (the engine raises on any error-severity finding).

    ``backend=None`` verifies against the backend the bind would execute
    on (``jax.default_backend()``) — what gates, e.g., an fp8-pinned entry
    reaching a host with no fp8 value-stream path."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    out = program_rules.check_program(program)
    out += schedule_rules.check_network(
        program, plan, batch=batch, dtype=dtype, backend=backend,
        params=params
    )
    return out
