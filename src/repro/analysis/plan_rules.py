"""Plan-cache rules: audit a cache file without executing anything.

A plan cache (``repro.tuning.cache``) is the deployment artifact that
decides which kernel every layer runs.  These rules parse the document and
every entry the way the loader and the engine would — schema version and
migration chain, per-entry method validity, the v5 BCSR block-shape
contract, the layer-key grammar, geometry self-consistency, tiling
divisibility, and the weight-structure tag — and verify that every pinned
Pallas/BCSR schedule actually dispatches at the geometry its key encodes.

Rules:

  plan.unreadable          file unreadable / invalid JSON / malformed
                           document or entry shape
  plan.schema_version      non-migratable schema version (error); a
                           migratable pre-v5 version reports as info
  plan.stale_bsr_no_block  a ``bsr`` entry with no block shape (pre-v5
                           document, or a hand-edited v5 entry) -- the
                           engine silently runs dense for it
  plan.key_unparsable      layer key does not match the key grammar
  plan.geometry_mismatch   key parses but encodes an impossible geometry
                           (kernel larger than the padded input, ...)
  plan.unknown_method      entry method outside the executor's METHODS
  plan.structure_tag       malformed ``_bk`` weight-structure tag (error);
                           an untagged bsr entry reports as info (it was
                           priced from the block-structured estimate)

Schedule infeasibilities found while replaying an entry at its key's
geometry are reported under the ``sched.*`` rules (same ids the network
check uses), so one rule id names one failure mode everywhere.  That
includes the v6 value-dtype axis: an entry pinning an unknown value dtype,
or one its key's backend cannot execute (fp8 off-TPU), reports as
``sched.value_dtype``; quantised entries replay their dispatch probes with
the narrow value itemsize and the scale-row budget, so a schedule that
only fits with f32 values — or only with quantised ones — is caught at the
dtype it will actually run.
"""

from __future__ import annotations

import json
import re
from typing import Any, Dict, List, Optional

from repro.analysis.diagnostics import REASON_RULES, Diagnostic
from repro.kernels.bsr_conv.ops import resolve_bsr_schedule
from repro.kernels.sparse_conv.ops import resolve_schedule
from repro.tuning.cache import CACHE_VERSION, MIGRATABLE_VERSIONS
from repro.tuning.space import (METHODS, VALUE_DTYPES, ConvGeometry,
                                allowed_value_dtypes)

RULES = {
    "plan.unreadable": (
        "error",
        "cache file unreadable, invalid JSON, or malformed entry shape",
    ),
    "plan.schema_version": (
        "error",
        "non-migratable schema version (info when migratable pre-v5)",
    ),
    "plan.stale_bsr_no_block": (
        "error",
        "bsr entry with no BCSR block shape; engine silently runs dense",
    ),
    "plan.key_unparsable": (
        "error",
        "layer key does not match the cache key grammar",
    ),
    "plan.geometry_mismatch": (
        "error",
        "layer key encodes an impossible geometry",
    ),
    "plan.unknown_method": (
        "error",
        "entry method outside the executor's method set",
    ),
    "plan.structure_tag": (
        "error",
        "malformed weight-structure tag (info when a bsr entry is untagged)",
    ),
}

# The grammar of tuning.cache.layer_key (+ the optional planner-appended
# weight-structure tag).  dtype/backend are single identifiers -- the key
# builder never embeds underscores in either.
KEY_RE = re.compile(
    r"^m(?P<m>\d+)_c(?P<c>\d+)_h(?P<h>\d+)w(?P<w>\d+)"
    r"_r(?P<r>\d+)s(?P<s>\d+)_st(?P<st>\d+)_p(?P<p>\d+)_n(?P<n>\d+)"
    r"_ep(?P<relu>[01])(?P<res>[01])_sp(?P<sp>[0-9.]+)"
    r"_(?P<dtype>[A-Za-z][A-Za-z0-9]*)_(?P<backend>[A-Za-z][A-Za-z0-9]*)"
    r"(?:_bk(?P<bk>[0-9.]+))?$"
)


def _diag(rule: str, severity: str, message: str, key: Optional[str] = None):
    return Diagnostic(
        rule=rule, severity=severity, message=message, location=key
    )


def geometry_from_key(match: "re.Match") -> ConvGeometry:
    """Reconstruct the ConvGeometry a layer key encodes (name = the key)."""
    g = match.groupdict()
    return ConvGeometry(
        name=match.string,
        m=int(g["m"]),
        c=int(g["c"]),
        h=int(g["h"]),
        w=int(g["w"]),
        r=int(g["r"]),
        s=int(g["s"]),
        stride=int(g["st"]),
        pad=int(g["p"]),
        sparsity=float(g["sp"]),
        batch=int(g["n"]),
        dtype=g["dtype"],
        relu=g["relu"] == "1",
        residual=g["res"] == "1",
    )


def _check_entry_schedule(
    key: str, g: ConvGeometry, entry: Dict[str, Any]
) -> List[Diagnostic]:
    """Replay a pallas/bsr entry's dispatch at its key's geometry."""
    out: List[Diagnostic] = []
    method = entry.get("method")
    fuse_res = bool(entry.get("fuse", False)) and g.residual
    itemsize = 2 if g.dtype in ("bfloat16", "float16") else 4
    vdt = entry.get("value_dtype", "float32") or "float32"
    if method == "pallas":
        tm = entry.get("tm")
        if tm is not None and (tm < 1 or g.m % tm):
            out.append(
                _diag(
                    "sched.nondividing_tm",
                    "error",
                    f"tm={tm} does not divide m={g.m}",
                    key,
                )
            )
            return out
        k = g.k_est(entry.get("pad_to") or 8)
        sched, reason = resolve_schedule(
            g.m,
            g.c,
            g.e,
            g.f,
            k,
            g.r,
            g.s,
            g.stride,
            tm=tm,
            te=entry.get("te"),
            tf=entry.get("tf"),
            fuse_res=fuse_res,
            pipeline=bool(entry.get("pipeline", False)),
            value_dtype=vdt,
        )
        if sched is None:
            out.append(
                _diag(
                    REASON_RULES[reason],
                    "error",
                    f"pallas entry does not dispatch at its key geometry "
                    f"(k~{k}): {reason}",
                    key,
                )
            )
        elif entry.get("pipeline", False) and not sched[3]:
            out.append(
                _diag(
                    "sched.pipeline_demoted",
                    "warning",
                    "entry asks for the double-buffered halo DMA but the "
                    "second halo buffer does not fit; the kernel silently "
                    "runs the blocking schedule",
                    key,
                )
            )
    elif method == "bsr":
        bm, bn = entry.get("block_m"), entry.get("block_n")
        if bm is None or bn is None:
            return out  # reported as plan.stale_bsr_no_block already
        gbm, gbn, _ = g.bsr_grid(int(bm), int(bn))
        sched, reason = resolve_bsr_schedule(
            g.c,
            g.e,
            g.f,
            g.r,
            g.s,
            g.stride,
            int(bm),
            int(bn),
            gbm,
            gbn,
            itemsize=itemsize,
            te=entry.get("te"),
            tf=entry.get("tf"),
            fuse_res=fuse_res,
            value_dtype=vdt,
        )
        if sched is None:
            out.append(
                _diag(
                    REASON_RULES[reason],
                    "error",
                    f"bsr entry (block={bm}x{bn}) does not dispatch at its "
                    f"key geometry: {reason}",
                    key,
                )
            )
    return out


def _check_entry(
    key: str, entry: Any, version: int
) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    if not isinstance(entry, dict) or "method" not in entry:
        out.append(
            _diag(
                "plan.unreadable",
                "error",
                "entry is not an object with a 'method' field",
                key,
            )
        )
        return out
    method = entry["method"]
    if method not in METHODS:
        out.append(
            _diag(
                "plan.unknown_method",
                "error",
                f"method {method!r} not one of {METHODS}",
                key,
            )
        )
        return out
    if method == "bsr" and (
        version < 5
        or entry.get("block_m") is None
        or entry.get("block_n") is None
    ):
        why = (
            f"pre-v{CACHE_VERSION} document: migrates with no block shape"
            if version < 5
            else "entry carries no block shape"
        )
        out.append(
            _diag(
                "plan.stale_bsr_no_block",
                "error",
                f"bsr entry cannot run ({why}); the engine silently falls "
                f"back to dense",
                key,
            )
        )
    m = KEY_RE.match(key)
    if m is None:
        out.append(
            _diag(
                "plan.key_unparsable",
                "error",
                "layer key does not match the cache key grammar "
                "m<M>_c<C>_h<H>w<W>_r<R>s<S>_st<ST>_p<P>_n<N>_ep<RL><RS>"
                "_sp<SP>_<dtype>_<backend>[_bk<frac>]",
                key,
            )
        )
        return out
    g = geometry_from_key(m)
    hp, wp = g.h + 2 * g.pad, g.w + 2 * g.pad
    if (
        min(g.m, g.c, g.h, g.w, g.r, g.s, g.stride) < 1
        or hp < g.r
        or wp < g.s
        or not 0.0 <= g.sparsity <= 1.0
    ):
        out.append(
            _diag(
                "plan.geometry_mismatch",
                "error",
                f"key encodes an impossible geometry (padded input "
                f"{hp}x{wp}, kernel {g.r}x{g.s}, stride {g.stride}, "
                f"sparsity {g.sparsity})",
                key,
            )
        )
        return out
    vdt = entry.get("value_dtype", "float32") or "float32"
    if method in ("pallas", "bsr") and vdt != "float32":
        if vdt not in VALUE_DTYPES:
            out.append(
                _diag(
                    "sched.value_dtype",
                    "error",
                    f"entry pins unknown value dtype {vdt!r}; one of "
                    f"{VALUE_DTYPES}",
                    key,
                )
            )
            return out
        backend = m.group("backend")
        allowed = allowed_value_dtypes(backend)
        if vdt not in allowed:
            out.append(
                _diag(
                    "sched.value_dtype",
                    "error",
                    f"entry pins value dtype {vdt!r} but its key's backend "
                    f"{backend!r} only executes {allowed}",
                    key,
                )
            )
            return out
    elif method not in ("pallas", "bsr") and vdt != "float32":
        out.append(
            _diag(
                "sched.value_dtype",
                "error",
                f"entry pins value dtype {vdt!r} on method {method!r}, "
                f"which has no quantised value-stream path",
                key,
            )
        )
        return out
    bk = m.group("bk")
    if bk is not None:
        try:
            frac = float(bk)
        except ValueError:
            frac = -1.0
        if not 0.0 <= frac <= 1.0:
            out.append(
                _diag(
                    "plan.structure_tag",
                    "error",
                    f"malformed weight-structure tag _bk{bk} (expected a "
                    f"kept-tile fraction in [0, 1])",
                    key,
                )
            )
    elif method == "bsr":
        out.append(
            _diag(
                "plan.structure_tag",
                "info",
                "untagged bsr entry: priced from the block-structured "
                "pruning estimate, not the bank's actual kept-tile "
                "structure",
                key,
            )
        )
    out += _check_entry_schedule(key, g, entry)
    return out


def check_plan_file(path: str) -> List[Diagnostic]:
    """Audit one plan-cache document; never raises, never executes."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as exc:
        return [
            _diag("plan.unreadable", "error", f"{path}: {exc}", None)
        ]
    out: List[Diagnostic] = []
    if not isinstance(doc, dict):
        return [
            _diag(
                "plan.unreadable",
                "error",
                f"{path}: document is not a JSON object",
                None,
            )
        ]
    version = doc.get("version")
    if version != CACHE_VERSION and version not in MIGRATABLE_VERSIONS:
        out.append(
            _diag(
                "plan.schema_version",
                "error",
                f"{path}: version {version!r} is neither current "
                f"({CACHE_VERSION}) nor migratable {MIGRATABLE_VERSIONS}",
                None,
            )
        )
        return out
    if version != CACHE_VERSION:
        out.append(
            _diag(
                "plan.schema_version",
                "info",
                f"{path}: migratable v{version} document; will be "
                f"re-persisted as v{CACHE_VERSION} on the next save",
                None,
            )
        )
    entries = doc.get("entries", {})
    if not isinstance(entries, dict):
        out.append(
            _diag(
                "plan.unreadable",
                "error",
                f"{path}: 'entries' is not an object",
                None,
            )
        )
        return out
    for key, entry in entries.items():
        out += _check_entry(key, entry, int(version))
    return out
