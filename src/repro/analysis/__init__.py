"""Pre-flight static verifier: find serving-time surprises before deploy.

Four rule packs over four layers of the stack, one diagnostic vocabulary:

  schedule_rules  drive the kernels' pure dispatch probes over every conv a
                  net can run: SMEM/VMEM budgets (incl. the pipeline's
                  second halo buffer), tiling divisibility, halo bounds,
                  the dtype policy
  plan_rules      audit a plan-cache file without executing: schema and
                  migration chain, stale pre-v5 bsr entries, key grammar,
                  geometry consistency, structure tags
  program_rules   structural checks on the lowered op program: SSA form,
                  geometry chaining, epilogue signatures
  ast_lints       parse the kernel sources: no host branching on traced
                  values, no allocation in the grid loop, f32 accumulators,
                  DMA start/wait pairing

``python -m repro.analysis check`` runs everything (docs:
``docs/static_analysis.md``); ``CnnEngine(..., strict=True)`` runs the
bind-scoped subset and raises :class:`PreflightError` on errors.
"""

from repro.analysis.diagnostics import (
    REASON_RULES,
    Diagnostic,
    PreflightError,
    Report,
)

__all__ = [
    "Diagnostic",
    "PreflightError",
    "REASON_RULES",
    "Report",
]
