"""AST lints over kernel sources: TPU-kernel hygiene, statically.

Parses the Pallas kernel modules (no import, no trace) and checks the
kernel bodies — top-level functions taking at least one ``*_ref``
parameter — for the classes of bug that trace cleanly on CPU interpret
mode but miscompile, stall, or silently mis-execute on the accelerator:

  lint.traced_branch  host-side ``if``/``while`` on a traced value
                      (``pl.program_id``/``pl.num_programs`` results, ref
                      loads, and anything derived from them).  Python
                      branches evaluate at trace time; branching on a
                      traced value either crashes late (ConcretizationError
                      on TPU) or silently bakes in one path.  Static
                      Python parameters (``if pipeline:``) are fine and
                      not flagged; ``jnp.where``/``pl.when``/ternary
                      expressions are the sanctioned forms.
  lint.grid_alloc     ``jnp.zeros``/``ones``/``full``/``empty`` inside the
                      innermost ``fori_loop`` body — a fresh allocation
                      per grid step defeats accumulator registerisation
                      (allocate outside, carry through the loop).
  lint.accum_dtype    an accumulator-style allocation (``jnp.zeros`` /
                      ``ones``/``full``) without an explicit f32 dtype —
                      the repo-wide policy is bf16/f16 inputs, float32
                      accumulate (``zeros_like``/``full_like`` inherit a
                      checked dtype and are exempt).
  lint.dma_pairing    a kernel body issuing async-copy ``.start()`` with
                      no matching ``.wait()`` (or vice versa) — an
                      unwaited DMA is a race on the destination buffer; a
                      wait with no start deadlocks.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.diagnostics import Diagnostic

RULES = {
    "lint.traced_branch": (
        "error",
        "host-side if/while on a traced value in a kernel body",
    ),
    "lint.grid_alloc": (
        "error",
        "array allocation inside the innermost fori_loop body",
    ),
    "lint.accum_dtype": (
        "error",
        "accumulator allocation without an explicit float32 dtype",
    ),
    "lint.dma_pairing": (
        "error",
        "async-copy start()/wait() not paired in a kernel body",
    ),
}

_TAINT_CALLS = {"program_id", "num_programs"}
_ALLOC_CALLS = {"zeros", "ones", "full", "empty"}
_F32_NAMES = {"float32"}


def _attr_name(func: ast.expr) -> Optional[str]:
    """The final attribute/name of a call target (``pl.program_id`` ->
    ``program_id``)."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _is_ref_load(node: ast.expr, ref_names: Set[str]) -> bool:
    """Whether ``node`` subscripts (loads from) a ``*_ref`` parameter."""
    if not isinstance(node, ast.Subscript):
        return False
    base = node.value
    while isinstance(base, ast.Attribute):
        base = base.value
    return isinstance(base, ast.Name) and base.id in ref_names


def _expr_tainted(
    node: ast.expr, tainted: Set[str], ref_names: Set[str]
) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in tainted:
            return True
        if isinstance(sub, ast.Call):
            if _attr_name(sub.func) in _TAINT_CALLS:
                return True
        if _is_ref_load(sub, ref_names):
            return True
    return False


def _target_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        names: List[str] = []
        for el in target.elts:
            names += _target_names(el)
        return names
    return []


def _tainted_names(fn: ast.FunctionDef, ref_names: Set[str]) -> Set[str]:
    """Names bound (anywhere in the kernel body, nested defs included) to a
    value derived from the grid position or a ref load.  Fixpoint over the
    assignment graph — no flow sensitivity needed for a lint."""
    tainted: Set[str] = set()
    assigns = [n for n in ast.walk(fn) if isinstance(n, (ast.Assign, ast.AugAssign))]
    for _ in range(len(assigns) + 1):
        grew = False
        for n in assigns:
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            names = [t for tgt in targets for t in _target_names(tgt)]
            if not names:
                continue
            if _expr_tainted(n.value, tainted, ref_names):
                for name in names:
                    if name not in tainted:
                        tainted.add(name)
                        grew = True
        if not grew:
            break
    return tainted


def _loop_body_fns(fn: ast.FunctionDef) -> List[ast.FunctionDef]:
    """The nested function defs passed to ``fori_loop`` as loop bodies."""
    body_names: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Call) and _attr_name(n.func) == "fori_loop":
            if len(n.args) >= 3 and isinstance(n.args[2], ast.Name):
                body_names.add(n.args[2].id)
    return [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.FunctionDef) and n.name in body_names
    ]


def _calls_fori_loop(fn: ast.FunctionDef) -> bool:
    return any(
        isinstance(n, ast.Call) and _attr_name(n.func) == "fori_loop"
        for n in ast.walk(fn)
    )


def _dtype_is_f32(node: ast.expr) -> bool:
    if isinstance(node, ast.Attribute):
        return node.attr in _F32_NAMES
    if isinstance(node, ast.Name):
        return node.id in _F32_NAMES
    if isinstance(node, ast.Constant):
        return node.value in ("float32", "f32")
    return False


def _alloc_dtype(call: ast.Call, name: str) -> Optional[ast.expr]:
    """The dtype argument of a jnp.zeros/ones/full/empty call, positional
    or keyword; None when absent."""
    for kw in call.keywords:
        if kw.arg == "dtype":
            return kw.value
    pos = 2 if name == "full" else 1  # full(shape, fill_value, dtype)
    if len(call.args) > pos:
        return call.args[pos]
    return None


def check_kernel_fn(
    fn: ast.FunctionDef, path: str
) -> List[Diagnostic]:
    """All four lints over one kernel body."""
    out: List[Diagnostic] = []
    ref_names = {
        a.arg
        for a in fn.args.args + fn.args.kwonlyargs
        if a.arg.endswith("_ref")
    }

    def diag(rule: str, node: ast.AST, message: str) -> None:
        out.append(
            Diagnostic(
                rule=rule,
                severity="error",
                message=message,
                layer=fn.name,
                location=f"{path}:{getattr(node, 'lineno', fn.lineno)}",
            )
        )

    # lint.traced_branch
    tainted = _tainted_names(fn, ref_names)
    for n in ast.walk(fn):
        if isinstance(n, (ast.If, ast.While)):
            if _expr_tainted(n.test, tainted, ref_names):
                kind = "if" if isinstance(n, ast.If) else "while"
                diag(
                    "lint.traced_branch",
                    n,
                    f"host-side `{kind}` on a traced value; use pl.when / "
                    f"jnp.where / lax.cond instead",
                )

    # lint.grid_alloc + lint.accum_dtype
    loop_bodies = _loop_body_fns(fn)
    innermost = {
        id(b) for b in loop_bodies if not _calls_fori_loop(b)
    }
    inner_nodes: Set[int] = set()
    for b in loop_bodies:
        if id(b) in innermost:
            inner_nodes.update(id(n) for n in ast.walk(b))
    for n in ast.walk(fn):
        if not isinstance(n, ast.Call):
            continue
        name = _attr_name(n.func)
        if name not in _ALLOC_CALLS:
            continue
        if id(n) in inner_nodes:
            diag(
                "lint.grid_alloc",
                n,
                f"jnp.{name} inside the innermost fori_loop body; allocate "
                f"outside the loop and carry it through",
            )
        dtype = _alloc_dtype(n, name)
        if dtype is None or not _dtype_is_f32(dtype):
            diag(
                "lint.accum_dtype",
                n,
                f"jnp.{name} without an explicit float32 dtype; kernel "
                f"accumulators must be f32 (bf16-in/f32-accumulate policy)",
            )

    # lint.dma_pairing
    starts = [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.Call) and _attr_name(n.func) == "start"
    ]
    waits = [
        n
        for n in ast.walk(fn)
        if isinstance(n, ast.Call) and _attr_name(n.func) == "wait"
    ]
    if bool(starts) != bool(waits):
        missing = "wait()" if starts else "start()"
        anchor = (starts or waits)[0]
        diag(
            "lint.dma_pairing",
            anchor,
            f"async-copy {'start' if starts else 'wait'}() with no "
            f"matching {missing} in this kernel body",
        )
    return out


def check_source(path: str) -> List[Diagnostic]:
    """Lint one Python source file; parse errors surface as diagnostics."""
    try:
        with open(path) as fh:
            tree = ast.parse(fh.read(), filename=path)
    except (OSError, SyntaxError) as exc:
        return [
            Diagnostic(
                rule="lint.traced_branch",
                severity="error",
                message=f"cannot parse {path}: {exc}",
                location=path,
            )
        ]
    out: List[Diagnostic] = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        args = node.args.args + node.args.kwonlyargs
        if any(a.arg.endswith("_ref") for a in args):
            out += check_kernel_fn(node, path)
    return out


def check_paths(paths: Iterable[str]) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for p in paths:
        out += check_source(p)
    return out
