"""Program rules: structural verification of a lowered op program.

``engine.lower`` emits a flat SSA-style program (value 0 = network input,
each op reads ``src`` ids and defines ``out``); the executor replays it as
pure dataflow without ever inspecting shapes.  That only works if the
program's static geometry actually chains — these rules re-derive every
op's input shape from its producers and check the recorded geometry against
it, plus the SSA discipline the executor assumes.

Rules:

  prog.ssa_form            an out id defined twice, or a src used before
                           (or without) definition
  prog.out_undefined       the program's result id is never defined
  prog.geometry_chain      an op's recorded input/output geometry does not
                           match what its producer actually yields
  prog.epilogue_signature  a fused epilogue operand (the bottleneck
                           shortcut ``res``) has the wrong shape for the
                           conv output it is added to
  prog.dead_value          an op's result is never consumed (warning)
  prog.unfused_relu        a ReluOp directly consumes a ConvOp output --
                           lowering should have fused it (warning)
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import Diagnostic
from repro.core.direct_conv import out_spatial
from repro.engine.program import (
    ConcatOp,
    ConvOp,
    FCOp,
    PoolOp,
    Program,
    ReluOp,
    ResidualAddOp,
)

RULES = {
    "prog.ssa_form": (
        "error",
        "value id defined twice or used before definition",
    ),
    "prog.out_undefined": (
        "error",
        "program result id is never defined",
    ),
    "prog.geometry_chain": (
        "error",
        "op geometry does not match its producer's output",
    ),
    "prog.epilogue_signature": (
        "error",
        "fused epilogue operand shape mismatch",
    ),
    "prog.dead_value": (
        "warning",
        "op result is never consumed",
    ),
    "prog.unfused_relu": (
        "warning",
        "ReLU on a conv output that lowering should have fused",
    ),
}

Shape = Tuple[int, int, int]  # (C, H, W)


def _srcs(op) -> List[int]:
    if isinstance(op, ConcatOp):
        return list(op.srcs)
    if isinstance(op, ResidualAddOp):
        return [op.a, op.b]
    srcs = [op.src]
    if isinstance(op, ConvOp) and op.res is not None:
        srcs.append(op.res)
    return srcs


def check_program(
    program: Program, *, net: Optional[str] = None
) -> List[Diagnostic]:
    """Structurally verify one lowered program (no execution)."""
    out: List[Diagnostic] = []
    shapes: Dict[int, Shape] = {0: tuple(program.in_shape)}
    producer: Dict[int, object] = {}
    consumed: Dict[int, int] = {}

    def err(rule: str, op, message: str, severity: str = "error") -> None:
        out.append(
            Diagnostic(
                rule=rule,
                severity=severity,
                message=message,
                net=net,
                layer=getattr(op, "name", None),
                location=f"op:{type(op).__name__}@{op.out}",
            )
        )

    for op in program.ops:
        if op.out in shapes:
            err(
                "prog.ssa_form",
                op,
                f"value {op.out} defined more than once",
            )
            continue
        missing = [s for s in _srcs(op) if s not in shapes]
        if missing:
            err(
                "prog.ssa_form",
                op,
                f"src value(s) {missing} used before definition",
            )
            continue
        for s in _srcs(op):
            consumed[s] = consumed.get(s, 0) + 1
        if isinstance(op, ConvOp):
            c, h, w = shapes[op.src]
            if (c, h, w) != (op.c, op.h, op.w):
                err(
                    "prog.geometry_chain",
                    op,
                    f"recorded input {(op.c, op.h, op.w)} but producer "
                    f"yields {(c, h, w)}",
                )
            e, f = out_spatial(op.h, op.w, op.k, op.k, op.stride, op.pad)
            if (e, f) != (op.e, op.f):
                err(
                    "prog.geometry_chain",
                    op,
                    f"recorded output {op.e}x{op.f} but conv arithmetic "
                    f"yields {e}x{f}",
                )
            if op.res is not None:
                rshape = shapes[op.res]
                if rshape != (op.m, op.e, op.f):
                    err(
                        "prog.epilogue_signature",
                        op,
                        f"fused shortcut shape {rshape} != conv output "
                        f"{(op.m, op.e, op.f)}",
                    )
            shapes[op.out] = (op.m, op.e, op.f)
        elif isinstance(op, PoolOp):
            c, h, w = shapes[op.src]
            if op.kind == "gap":
                e, f = 1, 1
            else:
                e, f = out_spatial(h, w, op.k, op.k, op.stride, op.pad)
            if (e, f) != (op.e, op.f):
                err(
                    "prog.geometry_chain",
                    op,
                    f"recorded pool output {op.e}x{op.f} but arithmetic "
                    f"yields {e}x{f}",
                )
            shapes[op.out] = (c, op.e, op.f)
        elif isinstance(op, ConcatOp):
            ss = [shapes[s] for s in op.srcs]
            if len({(h, w) for _, h, w in ss}) > 1:
                err(
                    "prog.geometry_chain",
                    op,
                    f"concat branches disagree spatially: "
                    f"{[(h, w) for _, h, w in ss]}",
                )
            shapes[op.out] = (sum(c for c, _, _ in ss), ss[0][1], ss[0][2])
        elif isinstance(op, ResidualAddOp):
            if shapes[op.a] != shapes[op.b]:
                err(
                    "prog.geometry_chain",
                    op,
                    f"residual add operands disagree: {shapes[op.a]} vs "
                    f"{shapes[op.b]}",
                )
            shapes[op.out] = shapes[op.a]
        elif isinstance(op, ReluOp):
            if isinstance(producer.get(op.src), ConvOp):
                err(
                    "prog.unfused_relu",
                    op,
                    f"ReLU on conv "
                    f"{producer[op.src].name!r} output; lowering should "
                    f"have fused it into the conv epilogue",
                    severity="warning",
                )
            shapes[op.out] = shapes[op.src]
        elif isinstance(op, FCOp):
            c, h, w = shapes[op.src]
            if op.in_f != c * h * w:
                err(
                    "prog.geometry_chain",
                    op,
                    f"recorded fan-in {op.in_f} but producer yields "
                    f"{c}x{h}x{w} = {c * h * w}",
                )
            shapes[op.out] = (op.out_f, 1, 1)
        else:
            err("prog.ssa_form", op, f"unknown op type {type(op).__name__}")
            shapes[op.out] = shapes.get(op.out, (0, 0, 0))
        producer[op.out] = op
    if program.out not in shapes:
        out.append(
            Diagnostic(
                rule="prog.out_undefined",
                severity="error",
                message=f"program result id {program.out} is never defined",
                net=net,
            )
        )
    for vid, op in producer.items():
        if vid != program.out and not consumed.get(vid):
            out.append(
                Diagnostic(
                    rule="prog.dead_value",
                    severity="warning",
                    message=f"value {vid} is never consumed",
                    net=net,
                    layer=getattr(op, "name", None),
                    location=f"op:{type(op).__name__}@{vid}",
                )
            )
    return out
