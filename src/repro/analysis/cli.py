"""CLI for the pre-flight static verifier.

Usage::

    python -m repro.analysis check                       # all nets + shipped
                                                         # plans + kernel lints
    python -m repro.analysis check --net resnet50 \\
        --plan-cache plans/resnet50.json --json
    python -m repro.analysis rules                       # rule catalogue

``check`` exits 0 when no error-severity diagnostics were found, 1
otherwise (warnings and infos never fail the run; CI gates on errors).
``--json`` prints the machine-readable report instead of the human one.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Pre-flight static verifier for kernel schedules, "
        "plan caches, and lowered programs.",
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    c = sub.add_parser(
        "check",
        help="verify networks, plan caches, and kernel sources",
    )
    c.add_argument(
        "--net",
        action="append",
        dest="nets",
        metavar="NAME",
        help="network to check (repeatable; default: all of "
        "alexnet/googlenet/resnet50)",
    )
    c.add_argument(
        "--plan-cache",
        action="append",
        dest="plan_caches",
        metavar="PATH",
        help="plan-cache file to audit and resolve against the nets "
        "(repeatable; default: each net's shipped plans/<net>.json)",
    )
    c.add_argument("--batch", type=int, default=1)
    c.add_argument("--image", type=int, default=224)
    c.add_argument("--dtype", default="float32")
    c.add_argument(
        "--backend",
        default="cpu",
        help="backend component of the cache keys to resolve (default: cpu, "
        "the shipped plans' key)",
    )
    c.add_argument(
        "--no-lints",
        action="store_true",
        help="skip the kernel-source AST lints",
    )
    c.add_argument(
        "--kernel-path",
        action="append",
        dest="kernel_paths",
        metavar="PATH",
        help="kernel source file to lint (repeatable; default: every .py "
        "under src/repro/kernels)",
    )
    c.add_argument(
        "--json",
        action="store_true",
        dest="as_json",
        help="machine-readable JSON report on stdout",
    )
    sub.add_parser("rules", help="print the rule catalogue")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    # Heavy imports after argparse so `--help` stays instant.
    from repro.analysis.checker import ALL_RULES, run_check

    if args.cmd == "rules":
        width = max(len(r) for r in ALL_RULES)
        for rule in sorted(ALL_RULES):
            severity, doc = ALL_RULES[rule]
            print(f"{rule:<{width}}  {severity:<7}  {doc}")
        return 0
    report = run_check(
        nets=args.nets,
        plan_caches=args.plan_caches,
        batch=args.batch,
        image=args.image,
        dtype=args.dtype,
        backend=args.backend,
        lint_paths=args.kernel_paths,
        lints=not args.no_lints,
    )
    if args.as_json:
        print(json.dumps(report.to_dict(), indent=2))
    else:
        print(report.format_human())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
