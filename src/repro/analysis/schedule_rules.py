"""Schedule rules: statically verify kernel dispatch for a lowered net.

Drives the kernels' own pure dispatch probes —
``kernels.sparse_conv.ops.resolve_schedule`` and
``kernels.bsr_conv.ops.resolve_bsr_schedule`` — over every conv op a
network can dispatch, without executing anything.  A plan entry that pins a
method the probe rejects is exactly the configuration that silently falls
back at serving time (the ``repro.telemetry.fallback`` reason codes), so
every such finding is an **error**, mapped through
``diagnostics.REASON_RULES`` to the rule that names the runtime reason.

Without a plan entry, the same probes run as method-space coverage
(severity ``info``): which sparse methods this geometry could ever run.

Rules:

  sched.smem_budget      scalar-prefetched operands (packed ELL indices /
                         BCSR block-column table + aux rows) bust SMEM
  sched.vmem_tiling      no VMEM-feasible tiling (or the plan-pinned one
                         busts the budget, counting the pipeline's second
                         halo buffer and the fused residual tile)
  sched.nondividing_tm   a pinned output-channel tile does not divide M
  sched.pipeline_demoted plan asks for the double-buffered halo DMA but
                         the second halo buffer does not fit -> the kernel
                         silently runs the blocking schedule (warning)
  sched.dtype_policy     geometry dtype outside the bf16-in/f32-accumulate
                         policy the kernels implement
  sched.halo_bounds      a resolved tile's halo window would read past the
                         padded input extent (invariant check)
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.analysis.diagnostics import REASON_RULES, Diagnostic
from repro.engine.program import ConvOp, Program
from repro.kernels.budget import halo_extent
from repro.kernels.bsr_conv.ops import resolve_bsr_schedule
from repro.kernels.sparse_conv.ops import resolve_schedule
from repro.tuning.planner import geometry_of_op

RULES = {
    "sched.smem_budget": (
        "error",
        "scalar-prefetched operands bust the SMEM budget",
    ),
    "sched.vmem_tiling": (
        "error",
        "no VMEM-feasible tiling for this geometry/schedule",
    ),
    "sched.nondividing_tm": (
        "error",
        "pinned output-channel tile does not divide M",
    ),
    "sched.pipeline_demoted": (
        "warning",
        "planned double-buffered halo DMA does not fit; kernel silently "
        "runs the blocking schedule",
    ),
    "sched.dtype_policy": (
        "error",
        "dtype outside the bf16/f32-in, f32-accumulate kernel policy",
    ),
    "sched.halo_bounds": (
        "error",
        "tile halo window reads past the padded input extent",
    ),
}

SUPPORTED_DTYPES = ("float32", "bfloat16", "float16")

# Default BCSR block probed when no plan pins one (engine.DEFAULT_BSR_BLOCK;
# re-declared to keep this module import-light).
_DEFAULT_BLOCK = (8, 128)


def _itemsize(dtype: str) -> int:
    return 2 if dtype in ("bfloat16", "float16") else 4


def _ell_k(
    op: ConvOp,
    pad_to: Optional[int],
    params: Optional[Dict[str, Any]],
    batch: int,
    dtype: str,
) -> int:
    """The padded ELL row length the dispatch would see: the bound bank's
    actual K when params are in hand, else the geometry estimate at the
    plan's pad_to bucket."""
    if params is not None:
        entry = params.get(op.name) or {}
        ell = entry.get("ell_auto") or entry.get("ell")
        if ell is not None:
            return int(ell.k)
    g = geometry_of_op(op, batch=batch, dtype=dtype)
    return g.k_est(pad_to or 8)


def _halo_check(
    op: ConvOp,
    te: int,
    tf: int,
    *,
    net: Optional[str],
) -> List[Diagnostic]:
    """Invariant: a resolved tile's halo'd input window must stay inside
    the padded input.  ``resolve_*`` clamps te/tf to (e, f), which bounds
    the halo by the padded extent — this guards that contract."""
    out = []
    hp, wp = op.h + 2 * op.pad, op.w + 2 * op.pad
    if halo_extent(te, op.stride, op.k) > hp or (
        halo_extent(tf, op.stride, op.k) > wp
    ):
        out.append(
            Diagnostic(
                rule="sched.halo_bounds",
                severity="error",
                message=(
                    f"tile ({te}, {tf}) halo "
                    f"({halo_extent(te, op.stride, op.k)}x"
                    f"{halo_extent(tf, op.stride, op.k)}) exceeds padded "
                    f"input {hp}x{wp}"
                ),
                net=net,
                layer=op.name,
            )
        )
    return out


def check_pallas_entry(
    op: ConvOp,
    entry: Any,
    *,
    net: Optional[str] = None,
    batch: int = 1,
    dtype: str = "float32",
    params: Optional[Dict[str, Any]] = None,
) -> List[Diagnostic]:
    """Verify one plan entry pinning ``method="pallas"`` dispatches to the
    Pallas kernel (not the silent csr-direct fallback)."""
    out: List[Diagnostic] = []
    k = _ell_k(op, entry.pad_to, params, batch, dtype)
    fuse_res = bool(entry.fuse) and op.res is not None
    sched, reason = resolve_schedule(
        op.m,
        op.c,
        op.e,
        op.f,
        k,
        op.k,
        op.k,
        op.stride,
        tm=entry.tm,
        te=entry.te,
        tf=entry.tf,
        fuse_res=fuse_res,
        pipeline=entry.pipeline,
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="error",
                message=(
                    f"plan pins pallas (tm={entry.tm} te={entry.te} "
                    f"tf={entry.tf} pad_to={entry.pad_to} k={k}) but "
                    f"dispatch falls back to csr-direct: {reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
        return out
    tm, te, tf, pipeline = sched
    if entry.pipeline and not pipeline:
        out.append(
            Diagnostic(
                rule="sched.pipeline_demoted",
                severity="warning",
                message=(
                    f"plan asks for the double-buffered halo DMA but the "
                    f"second halo buffer does not fit at (tm={tm}, te={te}, "
                    f"tf={tf}); the kernel silently runs the blocking "
                    f"schedule"
                ),
                net=net,
                layer=op.name,
            )
        )
    out += _halo_check(op, te, tf, net=net)
    return out


def check_bsr_entry(
    op: ConvOp,
    entry: Any,
    *,
    net: Optional[str] = None,
    batch: int = 1,
    dtype: str = "float32",
) -> List[Diagnostic]:
    """Verify one plan entry pinning ``method="bsr"`` dispatches to the MXU
    kernel (not the silent dense fallback)."""
    out: List[Diagnostic] = []
    if entry.block_m is None or entry.block_n is None:
        # Stale pre-v5 entry: the engine runs dense with
        # engine_reason="stale_plan_no_block".
        out.append(
            Diagnostic(
                rule="plan.stale_bsr_no_block",
                severity="error",
                message=(
                    "plan pins bsr with no block shape (stale pre-v5 "
                    "entry); the engine silently falls back to dense"
                ),
                net=net,
                layer=op.name,
            )
        )
        return out
    bm, bn = int(entry.block_m), int(entry.block_n)
    g = geometry_of_op(op, batch=batch, dtype=dtype)
    gbm, gbn, _ = g.bsr_grid(bm, bn)
    fuse_res = bool(entry.fuse) and op.res is not None
    sched, reason = resolve_bsr_schedule(
        op.c,
        op.e,
        op.f,
        op.k,
        op.k,
        op.stride,
        bm,
        bn,
        gbm,
        gbn,
        itemsize=_itemsize(dtype),
        te=entry.te,
        tf=entry.tf,
        fuse_res=fuse_res,
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="error",
                message=(
                    f"plan pins bsr (block={bm}x{bn} te={entry.te} "
                    f"tf={entry.tf}) but dispatch falls back to dense: "
                    f"{reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
        return out
    te, tf = sched
    out += _halo_check(op, te, tf, net=net)
    return out


def _probe_methods(
    op: ConvOp,
    *,
    net: Optional[str],
    batch: int,
    dtype: str,
    params: Optional[Dict[str, Any]],
) -> List[Diagnostic]:
    """Method-space coverage for an unplanned sparse conv: report (info)
    every sparse method this geometry can never dispatch."""
    out: List[Diagnostic] = []
    k = _ell_k(op, None, params, batch, dtype)
    sched, reason = resolve_schedule(
        op.m, op.c, op.e, op.f, k, op.k, op.k, op.stride
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="info",
                message=(
                    f"method pallas unavailable for this geometry "
                    f"(k={k}): {reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
    bm, bn = _DEFAULT_BLOCK
    g = geometry_of_op(op, batch=batch, dtype=dtype)
    gbm, gbn, _ = g.bsr_grid(bm, bn)
    sched, reason = resolve_bsr_schedule(
        op.c,
        op.e,
        op.f,
        op.k,
        op.k,
        op.stride,
        bm,
        bn,
        gbm,
        gbn,
        itemsize=_itemsize(dtype),
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="info",
                message=(
                    f"method bsr unavailable at the default {bm}x{bn} "
                    f"block: {reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
    return out


def check_network(
    program: Program,
    plan: Optional[Dict[str, Any]] = None,
    *,
    net: Optional[str] = None,
    batch: int = 1,
    dtype: str = "float32",
    params: Optional[Dict[str, Any]] = None,
) -> List[Diagnostic]:
    """Schedule-verify every conv op of a lowered program.

    ``plan`` is a ``{layer_name: PlanEntry}`` table (what ``CnnEngine``
    binds); ops it pins to a Pallas/BCSR method are verified to actually
    dispatch there (error otherwise).  Unplanned sparse ops get
    method-space coverage probes at severity ``info``.
    """
    out: List[Diagnostic] = []
    if dtype not in SUPPORTED_DTYPES:
        out.append(
            Diagnostic(
                rule="sched.dtype_policy",
                severity="error",
                message=(
                    f"dtype {dtype!r} outside the kernel policy "
                    f"{SUPPORTED_DTYPES} (inputs bf16/f16/f32, f32 "
                    f"accumulate)"
                ),
                net=net,
            )
        )
        return out
    for op in program.conv_ops:
        if op.sparsity <= 0:
            continue  # dense-kept layer: only ever runs dense
        entry = (plan or {}).get(op.name)
        if entry is None:
            out += _probe_methods(
                op, net=net, batch=batch, dtype=dtype, params=params
            )
        elif entry.method == "pallas":
            out += check_pallas_entry(
                op,
                entry,
                net=net,
                batch=batch,
                dtype=dtype,
                params=params,
            )
        elif entry.method == "bsr":
            out += check_bsr_entry(op, entry, net=net, batch=batch, dtype=dtype)
        elif entry.tm is not None and (entry.tm < 1 or op.m % entry.tm):
            # Non-Pallas methods ignore tm at execution time, but a
            # nondividing tm in the entry signals a stale/mis-keyed plan.
            out.append(
                Diagnostic(
                    rule="sched.nondividing_tm",
                    severity="warning",
                    message=(
                        f"plan entry carries tm={entry.tm} which does not "
                        f"divide m={op.m} (stale or mis-keyed plan?)"
                    ),
                    net=net,
                    layer=op.name,
                )
            )
    return out
