"""Schedule rules: statically verify kernel dispatch for a lowered net.

Drives the kernels' own pure dispatch probes —
``kernels.sparse_conv.ops.resolve_schedule`` and
``kernels.bsr_conv.ops.resolve_bsr_schedule`` — over every conv op a
network can dispatch, without executing anything.  A plan entry that pins a
method the probe rejects is exactly the configuration that silently falls
back at serving time (the ``repro.telemetry.fallback`` reason codes), so
every such finding is an **error**, mapped through
``diagnostics.REASON_RULES`` to the rule that names the runtime reason.

Without a plan entry, the same probes run as method-space coverage
(severity ``info``): which sparse methods this geometry could ever run.

Rules:

  sched.smem_budget      scalar-prefetched operands (packed ELL indices /
                         BCSR block-column table + aux rows) bust SMEM
  sched.vmem_tiling      no VMEM-feasible tiling (or the plan-pinned one
                         busts the budget, counting the pipeline's second
                         halo buffer and the fused residual tile)
  sched.nondividing_tm   a pinned output-channel tile does not divide M
  sched.pipeline_demoted plan asks for the double-buffered halo DMA but
                         the second halo buffer does not fit -> the kernel
                         silently runs the blocking schedule (warning)
  sched.dtype_policy     geometry dtype outside the bf16-in/f32-accumulate
                         policy the kernels implement
  sched.halo_bounds      a resolved tile's halo window would read past the
                         padded input extent (invariant check)
  sched.value_dtype      pinned value-storage dtype unknown, pinned on a
                         method with no quantised path, or not executable
                         on this backend (fp8 off-TPU) — the dtype policy
                         is ``tuning.space.allowed_value_dtypes``, the same
                         table the planner enumerates from
  sched.value_dtype_mismatch
                         the plan's pinned value dtype disagrees with an
                         already-quantised bound bank — the engine falls
                         back to dense with the ``value_dtype_mismatch``
                         runtime reason rather than silently re-coding the
                         bank
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.analysis.diagnostics import REASON_RULES, Diagnostic
from repro.engine.program import ConvOp, Program
from repro.kernels.budget import halo_extent
from repro.kernels.bsr_conv.ops import resolve_bsr_schedule
from repro.kernels.sparse_conv.ops import resolve_schedule
from repro.tuning.planner import geometry_of_op
from repro.tuning.space import VALUE_DTYPES, allowed_value_dtypes

RULES = {
    "sched.smem_budget": (
        "error",
        "scalar-prefetched operands bust the SMEM budget",
    ),
    "sched.vmem_tiling": (
        "error",
        "no VMEM-feasible tiling for this geometry/schedule",
    ),
    "sched.nondividing_tm": (
        "error",
        "pinned output-channel tile does not divide M",
    ),
    "sched.pipeline_demoted": (
        "warning",
        "planned double-buffered halo DMA does not fit; kernel silently "
        "runs the blocking schedule",
    ),
    "sched.dtype_policy": (
        "error",
        "dtype outside the bf16/f32-in, f32-accumulate kernel policy",
    ),
    "sched.halo_bounds": (
        "error",
        "tile halo window reads past the padded input extent",
    ),
    "sched.value_dtype": (
        "error",
        "pinned value-storage dtype unknown, on a method with no quantised "
        "path, or not executable on this backend",
    ),
    "sched.value_dtype_mismatch": (
        "error",
        "plan's pinned value dtype disagrees with the already-quantised "
        "bound bank; the engine silently runs dense",
    ),
}

SUPPORTED_DTYPES = ("float32", "bfloat16", "float16")

# Default BCSR block probed when no plan pins one (engine.DEFAULT_BSR_BLOCK;
# re-declared to keep this module import-light).
_DEFAULT_BLOCK = (8, 128)


def _itemsize(dtype: str) -> int:
    return 2 if dtype in ("bfloat16", "float16") else 4


def _ell_k(
    op: ConvOp,
    pad_to: Optional[int],
    params: Optional[Dict[str, Any]],
    batch: int,
    dtype: str,
) -> int:
    """The padded ELL row length the dispatch would see: the bound bank's
    actual K when params are in hand, else the geometry estimate at the
    plan's pad_to bucket."""
    if params is not None:
        entry = params.get(op.name) or {}
        ell = entry.get("ell_auto") or entry.get("ell")
        if ell is not None:
            return int(ell.k)
    g = geometry_of_op(op, batch=batch, dtype=dtype)
    return g.k_est(pad_to or 8)


def _halo_check(
    op: ConvOp,
    te: int,
    tf: int,
    *,
    net: Optional[str],
) -> List[Diagnostic]:
    """Invariant: a resolved tile's halo'd input window must stay inside
    the padded input.  ``resolve_*`` clamps te/tf to (e, f), which bounds
    the halo by the padded extent — this guards that contract."""
    out = []
    hp, wp = op.h + 2 * op.pad, op.w + 2 * op.pad
    if halo_extent(te, op.stride, op.k) > hp or (
        halo_extent(tf, op.stride, op.k) > wp
    ):
        out.append(
            Diagnostic(
                rule="sched.halo_bounds",
                severity="error",
                message=(
                    f"tile ({te}, {tf}) halo "
                    f"({halo_extent(te, op.stride, op.k)}x"
                    f"{halo_extent(tf, op.stride, op.k)}) exceeds padded "
                    f"input {hp}x{wp}"
                ),
                net=net,
                layer=op.name,
            )
        )
    return out


def check_value_dtype(
    entry: Any,
    *,
    backend: str,
    bank_dtype: Optional[str] = None,
    net: Optional[str] = None,
    layer: Optional[str] = None,
    location: Optional[str] = None,
) -> List[Diagnostic]:
    """Value-dtype policy for one pallas/bsr plan entry.

    ``sched.value_dtype``: the pinned dtype is unknown, or the backend the
    entry is keyed for cannot execute it (``allowed_value_dtypes`` — the
    planner's own candidate table, so planner and verifier can never
    disagree about what is runnable).  ``sched.value_dtype_mismatch``: the
    bound bank is already quantised at a *different* dtype than the plan
    pins (``bank_dtype``, when the caller has params in hand) — the exact
    configuration the engine refuses with the ``value_dtype_mismatch``
    runtime fallback.  A f32 bank under a narrow plan is healthy (the
    engine quantises in-trace) and reports nothing.
    """
    out: List[Diagnostic] = []
    vdt = getattr(entry, "value_dtype", None)
    if vdt is None:
        vdt = "float32"
    if vdt not in VALUE_DTYPES:
        out.append(
            Diagnostic(
                rule="sched.value_dtype",
                severity="error",
                message=(
                    f"plan pins unknown value dtype {vdt!r}; one of "
                    f"{VALUE_DTYPES}"
                ),
                net=net,
                layer=layer,
                location=location,
            )
        )
        return out
    allowed = allowed_value_dtypes(backend)
    if vdt not in allowed:
        out.append(
            Diagnostic(
                rule="sched.value_dtype",
                severity="error",
                message=(
                    f"plan pins value dtype {vdt!r} but backend "
                    f"{backend!r} only executes {allowed}; dispatch would "
                    f"run a value stream the hardware cannot stream"
                ),
                net=net,
                layer=layer,
                location=location,
            )
        )
        return out
    if (
        bank_dtype is not None
        and bank_dtype != "float32"
        and bank_dtype != vdt
    ):
        out.append(
            Diagnostic(
                rule="sched.value_dtype_mismatch",
                severity="error",
                message=(
                    f"plan pins value dtype {vdt!r} but the bound bank is "
                    f"already quantised as {bank_dtype!r}; the engine falls "
                    f"back to dense (value_dtype_mismatch) rather than "
                    f"silently re-coding the bank"
                ),
                net=net,
                layer=layer,
                location=location,
            )
        )
    return out


def _bank_dtype(bank: Any) -> Optional[str]:
    """The value-storage dtype of a bound bank (None without one)."""
    if bank is None:
        return None
    if getattr(bank, "scale", None) is None:
        return "float32"
    return bank.value_dtype


def check_pallas_entry(
    op: ConvOp,
    entry: Any,
    *,
    net: Optional[str] = None,
    batch: int = 1,
    dtype: str = "float32",
    backend: str = "cpu",
    params: Optional[Dict[str, Any]] = None,
) -> List[Diagnostic]:
    """Verify one plan entry pinning ``method="pallas"`` dispatches to the
    Pallas kernel (not the silent csr-direct fallback)."""
    out: List[Diagnostic] = []
    bank = None
    if params is not None:
        pentry = params.get(op.name) or {}
        bank = pentry.get("ell_auto") or pentry.get("ell")
    out += check_value_dtype(
        entry, backend=backend, bank_dtype=_bank_dtype(bank), net=net,
        layer=op.name)
    if out:
        return out
    vdt = getattr(entry, "value_dtype", "float32") or "float32"
    k = _ell_k(op, entry.pad_to, params, batch, dtype)
    fuse_res = bool(entry.fuse) and op.res is not None
    sched, reason = resolve_schedule(
        op.m,
        op.c,
        op.e,
        op.f,
        k,
        op.k,
        op.k,
        op.stride,
        tm=entry.tm,
        te=entry.te,
        tf=entry.tf,
        fuse_res=fuse_res,
        pipeline=entry.pipeline,
        value_dtype=vdt,
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="error",
                message=(
                    f"plan pins pallas (tm={entry.tm} te={entry.te} "
                    f"tf={entry.tf} pad_to={entry.pad_to} k={k}) but "
                    f"dispatch falls back to csr-direct: {reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
        return out
    tm, te, tf, pipeline = sched
    if entry.pipeline and not pipeline:
        out.append(
            Diagnostic(
                rule="sched.pipeline_demoted",
                severity="warning",
                message=(
                    f"plan asks for the double-buffered halo DMA but the "
                    f"second halo buffer does not fit at (tm={tm}, te={te}, "
                    f"tf={tf}); the kernel silently runs the blocking "
                    f"schedule"
                ),
                net=net,
                layer=op.name,
            )
        )
    out += _halo_check(op, te, tf, net=net)
    return out


def check_bsr_entry(
    op: ConvOp,
    entry: Any,
    *,
    net: Optional[str] = None,
    batch: int = 1,
    dtype: str = "float32",
    backend: str = "cpu",
    params: Optional[Dict[str, Any]] = None,
) -> List[Diagnostic]:
    """Verify one plan entry pinning ``method="bsr"`` dispatches to the MXU
    kernel (not the silent dense fallback)."""
    out: List[Diagnostic] = []
    bank = None
    if params is not None:
        pentry = params.get(op.name) or {}
        bank = pentry.get("bcsr_auto")
        if bank is not None and entry.block_m is not None and bank.block != (
            entry.block_m,
            entry.block_n,
        ):
            # Block mismatch: the engine rebuilds an f32 bank from the
            # dense weights, so the prebuilt bank's dtype is irrelevant.
            bank = None
    out += check_value_dtype(
        entry, backend=backend, bank_dtype=_bank_dtype(bank), net=net,
        layer=op.name)
    if out:
        return out
    vdt = getattr(entry, "value_dtype", "float32") or "float32"
    if entry.block_m is None or entry.block_n is None:
        # Stale pre-v5 entry: the engine runs dense with
        # engine_reason="stale_plan_no_block".
        out.append(
            Diagnostic(
                rule="plan.stale_bsr_no_block",
                severity="error",
                message=(
                    "plan pins bsr with no block shape (stale pre-v5 "
                    "entry); the engine silently falls back to dense"
                ),
                net=net,
                layer=op.name,
            )
        )
        return out
    bm, bn = int(entry.block_m), int(entry.block_n)
    g = geometry_of_op(op, batch=batch, dtype=dtype)
    gbm, gbn, _ = g.bsr_grid(bm, bn)
    fuse_res = bool(entry.fuse) and op.res is not None
    sched, reason = resolve_bsr_schedule(
        op.c,
        op.e,
        op.f,
        op.k,
        op.k,
        op.stride,
        bm,
        bn,
        gbm,
        gbn,
        itemsize=_itemsize(dtype),
        te=entry.te,
        tf=entry.tf,
        fuse_res=fuse_res,
        value_dtype=vdt,
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="error",
                message=(
                    f"plan pins bsr (block={bm}x{bn} te={entry.te} "
                    f"tf={entry.tf}) but dispatch falls back to dense: "
                    f"{reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
        return out
    te, tf = sched
    out += _halo_check(op, te, tf, net=net)
    return out


def _probe_methods(
    op: ConvOp,
    *,
    net: Optional[str],
    batch: int,
    dtype: str,
    params: Optional[Dict[str, Any]],
) -> List[Diagnostic]:
    """Method-space coverage for an unplanned sparse conv: report (info)
    every sparse method this geometry can never dispatch."""
    out: List[Diagnostic] = []
    k = _ell_k(op, None, params, batch, dtype)
    sched, reason = resolve_schedule(
        op.m, op.c, op.e, op.f, k, op.k, op.k, op.stride
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="info",
                message=(
                    f"method pallas unavailable for this geometry "
                    f"(k={k}): {reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
    bm, bn = _DEFAULT_BLOCK
    g = geometry_of_op(op, batch=batch, dtype=dtype)
    gbm, gbn, _ = g.bsr_grid(bm, bn)
    sched, reason = resolve_bsr_schedule(
        op.c,
        op.e,
        op.f,
        op.k,
        op.k,
        op.stride,
        bm,
        bn,
        gbm,
        gbn,
        itemsize=_itemsize(dtype),
    )
    if sched is None:
        out.append(
            Diagnostic(
                rule=REASON_RULES[reason],
                severity="info",
                message=(
                    f"method bsr unavailable at the default {bm}x{bn} "
                    f"block: {reason}"
                ),
                net=net,
                layer=op.name,
            )
        )
    return out


def check_network(
    program: Program,
    plan: Optional[Dict[str, Any]] = None,
    *,
    net: Optional[str] = None,
    batch: int = 1,
    dtype: str = "float32",
    backend: str = "cpu",
    params: Optional[Dict[str, Any]] = None,
) -> List[Diagnostic]:
    """Schedule-verify every conv op of a lowered program.

    ``plan`` is a ``{layer_name: PlanEntry}`` table (what ``CnnEngine``
    binds); ops it pins to a Pallas/BCSR method are verified to actually
    dispatch there (error otherwise).  Unplanned sparse ops get
    method-space coverage probes at severity ``info``.
    """
    out: List[Diagnostic] = []
    if dtype not in SUPPORTED_DTYPES:
        out.append(
            Diagnostic(
                rule="sched.dtype_policy",
                severity="error",
                message=(
                    f"dtype {dtype!r} outside the kernel policy "
                    f"{SUPPORTED_DTYPES} (inputs bf16/f16/f32, f32 "
                    f"accumulate)"
                ),
                net=net,
            )
        )
        return out
    for op in program.conv_ops:
        if op.sparsity <= 0:
            continue  # dense-kept layer: only ever runs dense
        entry = (plan or {}).get(op.name)
        if entry is None:
            out += _probe_methods(
                op, net=net, batch=batch, dtype=dtype, params=params
            )
        elif entry.method == "pallas":
            out += check_pallas_entry(
                op,
                entry,
                net=net,
                batch=batch,
                dtype=dtype,
                backend=backend,
                params=params,
            )
        elif entry.method == "bsr":
            out += check_bsr_entry(op, entry, net=net, batch=batch,
                                   dtype=dtype, backend=backend,
                                   params=params)
        elif entry.tm is not None and (entry.tm < 1 or op.m % entry.tm):
            # Non-Pallas methods ignore tm at execution time, but a
            # nondividing tm in the entry signals a stale/mis-keyed plan.
            out.append(
                Diagnostic(
                    rule="sched.nondividing_tm",
                    severity="warning",
                    message=(
                        f"plan entry carries tm={entry.tm} which does not "
                        f"divide m={op.m} (stale or mis-keyed plan?)"
                    ),
                    net=net,
                    layer=op.name,
                )
            )
    return out
