"""Elastic scaling: re-shard a checkpoint onto a different mesh.

Because checkpoints store *global* arrays per leaf (host shard files union to
the full tensor) and shardings are derived from logical rules, moving between
mesh shapes is: build new mesh -> resolve specs -> restore with placement.
``plan_remesh`` decides the replacement mesh after losing nodes (drop the
data-parallel extent first — gradient noise scale degrades gracefully;
the model axis extent is load-bearing for memory).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def plan_remesh(n_alive: int, *, model: int = 16,
                pod_axis: bool = False) -> Optional[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """Largest (data, model) mesh fitting the surviving chips.

    Keeps the model axis fixed (sharding of weights must still fit HBM) and
    shrinks data parallelism to the largest power of two that fits.
    Returns None if fewer than one model replica survives.
    """
    if n_alive < model:
        return None
    data = 1
    while data * 2 * model <= n_alive:
        data *= 2
    if pod_axis and data >= 2:
        return ((2, data // 2, model), ("pod", "data", "model"))
    return ((data, model), ("data", "model"))


def build_mesh(plan: Tuple[Tuple[int, ...], Tuple[str, ...]],
               devices=None) -> Mesh:
    shape, axes = plan
    devs = devices if devices is not None else jax.devices()
    need = int(np.prod(shape))
    return Mesh(np.asarray(devs[:need]).reshape(shape), axes)
