"""Fault-tolerance runtime: checkpoint/restart, failure retry, stragglers.

At thousand-node scale the failure model is: (a) hard host loss -> the step
raises (collective timeout / device error); (b) soft stragglers -> step time
inflates without failing.  The pieces here:

  StragglerMonitor -- per-step wall-time EWMA + deviation; flags steps (and,
      with per-host heartbeat timings fed in, hosts) that exceed k sigma.
      On real deployments the flag triggers the elastic re-mesh path.
  FailureDetector  -- wraps a step callable; classifies exceptions into
      retryable (transient collective/network) vs fatal; counts strikes.
  StepRunner       -- the restart loop: run step, on retryable failure
      restore the latest committed checkpoint and continue; on repeated
      failure escalate to the caller (scheduler would then re-mesh).
  Backoff          -- deterministic capped-exponential retry-delay policy
      (no jitter: the serving chaos harness asserts exact schedules).

``FailureDetector`` and ``StragglerMonitor`` are shared with the CNN
serving tier (``repro.serving.robust``): the same retryable-vs-fatal
classification that restarts a training step decides whether a serve-step
failure re-enqueues its requests with backoff or rejects them.

These are deliberately framework-level (pure Python around the jitted step):
the jitted computation stays simple and the policy stays inspectable.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, Optional, Tuple

RETRYABLE_MARKERS = (
    "DEADLINE_EXCEEDED", "UNAVAILABLE", "ABORTED", "collective",
    "socket closed", "connection reset", "heartbeat",
)


class StragglerMonitor:
    """EWMA step-time monitor with k-sigma straggler flagging."""

    def __init__(self, alpha: float = 0.1, k_sigma: float = 3.0,
                 warmup_steps: int = 5):
        self.alpha = alpha
        self.k = k_sigma
        self.warmup = warmup_steps
        self.mean: Optional[float] = None
        self.var = 0.0
        self.n = 0
        self.flags: collections.deque = collections.deque(maxlen=100)

    def observe(self, dt: float) -> bool:
        """Record one step time; returns True if flagged as straggling."""
        self.n += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        if self.n > self.warmup:
            sigma = max(self.var ** 0.5, 1e-6)
            if dt > self.mean + self.k * sigma and dt > 1.2 * self.mean:
                is_straggler = True
                self.flags.append((self.n, dt, self.mean))
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler

    def observe_hosts(self, host_times: Dict[int, float]) -> list:
        """Flag specific hosts whose step contribution lags the median."""
        if not host_times:
            return []
        ts = sorted(host_times.values())
        med = ts[len(ts) // 2]
        return [h for h, t in host_times.items()
                if t > 1.5 * med and t - med > 1.0]


class Backoff:
    """Capped exponential retry delay: ``base * mult**attempt``, <= ``cap``.

    Deliberately jitter-free — retry schedules must be reproducible under
    the seeded fault-injection harness (``repro.serving.chaos``), and the
    serving tier spreads retries by request identity, not randomness.
    """

    def __init__(self, base_s: float = 0.05, mult: float = 2.0,
                 cap_s: float = 2.0):
        if base_s <= 0 or mult < 1.0:
            raise ValueError(f"bad backoff policy base={base_s} mult={mult}")
        self.base_s = base_s
        self.mult = mult
        self.cap_s = cap_s

    def delay_s(self, attempt: int) -> float:
        """Delay before retry number ``attempt`` (0-based: the first retry
        waits ``base_s``)."""
        return min(self.cap_s, self.base_s * self.mult ** max(attempt, 0))


class FailureDetector:
    def __init__(self, max_strikes: int = 3):
        self.max_strikes = max_strikes
        self.strikes = 0

    def classify(self, exc: BaseException) -> str:
        msg = str(exc)
        if any(m.lower() in msg.lower() for m in RETRYABLE_MARKERS):
            return "retryable"
        return "fatal"

    def record(self, exc: BaseException) -> str:
        kind = self.classify(exc)
        if kind == "retryable":
            self.strikes += 1
            if self.strikes >= self.max_strikes:
                return "escalate"
        return kind

    def reset(self) -> None:
        self.strikes = 0


class StepRunner:
    """Checkpoint/restart training loop wrapper.

    run() executes steps, saving every ``ckpt_every``; a retryable failure
    restores the latest committed checkpoint (recompiling is the scheduler's
    concern) and resumes; repeated failures escalate.
    """

    def __init__(self, step_fn: Callable[[Any, Any], Tuple[Any, Dict]],
                 ckpt_manager, loader_factory: Callable[[int], Any], *,
                 ckpt_every: int = 100,
                 monitor: Optional[StragglerMonitor] = None,
                 detector: Optional[FailureDetector] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.loader_factory = loader_factory
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StragglerMonitor()
        self.detector = detector or FailureDetector()

    def run(self, state: Any, start_step: int, num_steps: int,
            *, on_metrics: Optional[Callable[[int, Dict], None]] = None):
        step = start_step
        loader = self.loader_factory(step)
        while step < start_step + num_steps:
            batch = next(loader)
            t0 = time.time()
            try:
                state, metrics = self.step_fn(state, batch)
                # block so failures surface inside the try and timings are real
                metrics = {k: float(v) for k, v in metrics.items()}
            except Exception as exc:  # noqa: BLE001 - classified below
                verdict = self.detector.record(exc)
                if verdict in ("fatal", "escalate"):
                    self.ckpt.wait()
                    raise
                restored, ck_step = self.ckpt.restore_latest(state)
                if restored is None:
                    raise
                state = restored
                step = ck_step
                loader.close()
                loader = self.loader_factory(step)
                continue
            self.detector.reset()
            dt = time.time() - t0
            if self.monitor.observe(dt) and on_metrics:
                on_metrics(step, {"straggler_flag": dt, **metrics})
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(state, step)
            if on_metrics:
                on_metrics(step, metrics)
        loader.close()
        self.ckpt.wait()
        return state, step
