from repro.runtime.fault_tolerance import (Backoff, FailureDetector,
                                           StepRunner, StragglerMonitor)
from repro.runtime.elastic import build_mesh, plan_remesh

__all__ = ["Backoff", "FailureDetector", "StepRunner", "StragglerMonitor",
           "build_mesh", "plan_remesh"]
