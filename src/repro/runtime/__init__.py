from repro.runtime.fault_tolerance import (FailureDetector, StepRunner,
                                           StragglerMonitor)
from repro.runtime.elastic import build_mesh, plan_remesh

__all__ = ["FailureDetector", "StepRunner", "StragglerMonitor",
           "build_mesh", "plan_remesh"]
