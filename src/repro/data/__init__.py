from repro.data.pipeline import (DataConfig, SyntheticLMDataset, ShardedLoader,
                                 make_loader)

__all__ = ["DataConfig", "SyntheticLMDataset", "ShardedLoader", "make_loader"]
