"""Deterministic sharded data pipeline.

Production posture (1000+ nodes): every host deterministically derives its
own shard of each global batch from (seed, step, host_id) — no coordinator,
no filesystem contention, bit-identical restart after failover at any step
(the checkpoint only needs to store ``step``).  A background prefetch thread
keeps ``prefetch`` batches ready so host compute overlaps device compute.

The token source is a synthetic-but-deterministic LM stream (counter-based
threefry keys); swapping in a real tokenised corpus only replaces
``SyntheticLMDataset.batch_for``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2
    embed_dim: int = 0          # >0: emit precomputed embeddings (stub frontends)


class SyntheticLMDataset:
    """Counter-based deterministic token stream; O(1) random access by step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.host_batch = cfg.global_batch // cfg.n_hosts

    def batch_for(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 0, step, cfg.host_id]))
        shape = (self.host_batch, cfg.seq_len + 1)
        toks = rng.integers(0, cfg.vocab, size=shape, dtype=np.int32)
        out: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.embed_dim:
            emb = rng.standard_normal(
                (self.host_batch, cfg.seq_len, cfg.embed_dim)).astype(np.float32)
            out = {"embeds": emb, "labels": toks[:, 1:]}
        return out


class ShardedLoader:
    """Background prefetch over a dataset; yields host-local numpy batches."""

    def __init__(self, dataset: SyntheticLMDataset, start_step: int = 0):
        self.dataset = dataset
        self.step = start_step
        self._q: "queue.Queue[Any]" = queue.Queue(
            maxsize=max(dataset.cfg.prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        s = self.step
        while not self._stop.is_set():
            batch = self.dataset.batch_for(s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self) -> None:
        self._stop.set()


def make_loader(cfg: DataConfig, start_step: int = 0) -> ShardedLoader:
    return ShardedLoader(SyntheticLMDataset(cfg), start_step=start_step)
