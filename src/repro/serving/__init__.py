from repro.serving.chaos import (Arrival, ChaosConfig, ChaosFatalError,
                                 ChaosInjector, ChaosRetryableError,
                                 arrival_trace, corrupt_plan_cache_file,
                                 slice_net)
from repro.serving.robust import (LADDER_REASONS, REJECT_REASONS, BucketSpec,
                                  InferenceRequest, LadderEvent,
                                  RobustCnnServer, SloReport, VirtualClock,
                                  WallClock)
from repro.serving.scheduler import (ContinuousBatcher, DrainExhaustedWarning,
                                     DrainResult, Request, ServeEngine,
                                     StragglerTickWarning)

__all__ = [
    "Arrival", "BucketSpec", "ChaosConfig", "ChaosFatalError",
    "ChaosInjector", "ChaosRetryableError", "ContinuousBatcher",
    "DrainExhaustedWarning", "DrainResult", "InferenceRequest",
    "LADDER_REASONS", "LadderEvent", "REJECT_REASONS", "Request",
    "RobustCnnServer", "ServeEngine", "SloReport", "StragglerTickWarning",
    "VirtualClock", "WallClock", "arrival_trace", "corrupt_plan_cache_file",
    "slice_net",
]
