from repro.serving.scheduler import (Request, ContinuousBatcher, ServeEngine)

__all__ = ["Request", "ContinuousBatcher", "ServeEngine"]
