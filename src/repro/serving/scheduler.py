"""Continuous-batching serving engine (slot-based, vLLM-style scheduling
adapted to fixed-shape JAX decode steps).

The jitted ``serve_step`` has a fixed batch of B *slots*; the scheduler
admits requests into free slots, steps the whole batch every tick, and
retires slots whose request hit its token budget or produced EOS.  Because
the cache tensor shape never changes, there is exactly ONE compiled decode
program regardless of arrival pattern — the property that makes this design
deployable on TPU serving pods.

Position bookkeeping: the model's decode path takes a *scalar* ``cur_len``
— every slot's KV is written at one shared position per tick.  The engine
therefore drives a monotonic write cursor (reset only when the batch fully
drains) so the write position never regresses and live KV is never
clobbered, and tracks a per-slot ``pos`` for retirement so each request is
retired at its own depth.  Mid-stream admission is capacity-gated: a
request only enters a free slot when the cache depth remaining above the
cursor covers its prompt + generation budget; otherwise it waits for the
batch to drain (continuous batching degrades to waves near capacity —
correct, if not latency-optimal).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.runtime.fault_tolerance import StragglerMonitor


class DrainExhaustedWarning(UserWarning):
    """``run_until_drained`` hit ``max_ticks`` with requests still pending."""


class StragglerTickWarning(UserWarning):
    """A serving tick straggled (k-sigma above the EWMA tick time)."""


class DrainResult(List["Request"]):
    """``run_until_drained``'s return value: the finished-request list
    (drop-in for existing callers) plus the drain status.

    ``drained`` is False when the tick budget ran out with requests still
    queued or active — previously a *silently incomplete* return; callers
    that must not lose requests check it (or count
    ``serving.drain_exhausted``).
    """

    drained: bool = True
    ticks: int = 0
    pending_queued: int = 0
    pending_active: int = 0

    @property
    def pending(self) -> int:
        return self.pending_queued + self.pending_active


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pos: int = 0              # next KV write position for this slot
    prompt_cursor: int = 0    # how much of the prompt has been fed

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    """Admission + retirement policy over B fixed slots."""

    def __init__(self, n_slots: int, max_len: int):
        self.slots = [_Slot() for _ in range(n_slots)]
        self.max_len = max_len
        self.queue: List[Request] = []
        # Oversize-rejected requests: popped from the queue at admission, so
        # they must be tracked here or they vanish from the finished list.
        self.rejected: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self, budget: Optional[int] = None) -> int:
        """Fill free slots from the queue.

        ``budget`` is the cache depth still available (engine: max_len minus
        the current write cursor).  Requests that can never fit max_len are
        rejected outright; requests that merely don't fit the *remaining*
        budget stay queued until the batch drains and the cursor resets.
        """
        budget = self.max_len if budget is None else budget
        admitted = 0
        for slot in self.slots:
            if not self.queue:
                break
            if slot.free:
                req = self.queue[0]
                if len(req.prompt) + req.max_new_tokens > self.max_len:
                    self.queue.pop(0)
                    req.done = True  # reject oversize; surfaced to caller
                    self.rejected.append(req)
                    if telemetry.is_enabled():
                        telemetry.counter("serving.rejections").inc()
                    continue
                if len(req.prompt) + req.max_new_tokens > budget:
                    break  # not enough cache left this wave: wait, don't drop
                self.queue.pop(0)
                slot.request = req
                slot.pos = 0
                slot.prompt_cursor = 0
                admitted += 1
        if admitted and telemetry.is_enabled():
            telemetry.counter("serving.admissions").inc(admitted)
        return admitted

    def retire(self) -> List[Request]:
        out = []
        for slot in self.slots:
            req = slot.request
            if req is None:
                continue
            hit_budget = len(req.output) >= req.max_new_tokens
            hit_eos = (req.eos_id is not None and req.output
                       and req.output[-1] == req.eos_id)
            hit_cap = slot.pos >= self.max_len - 1
            if hit_budget or hit_eos or hit_cap:
                req.done = True
                out.append(req)
                slot.request = None
        if out and telemetry.is_enabled():
            telemetry.counter("serving.retirements").inc(len(out))
        return out

    @property
    def active(self) -> int:
        return sum(0 if s.free else 1 for s in self.slots)


class ServeEngine:
    """Drives a jitted serve_step over the batcher's slots.

    serve_step(params, tokens (B,1), cache, cur_len ()) -> (next (B,), cache)
    """

    def __init__(self, serve_step: Callable, params, cache, n_slots: int,
                 max_len: int, pad_id: int = 0,
                 monitor: Optional[StragglerMonitor] = None):
        self.step = serve_step
        self.params = params
        self.cache = cache
        self.batcher = ContinuousBatcher(n_slots, max_len)
        self.n_slots = n_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self._tick = 0
        # Shared KV write position: monotonic while any slot is live, reset
        # only when the batch fully drains.  Taking max(slot.pos) instead
        # would regress when the deepest slot retires and overwrite live KV.
        self._cursor = 0
        # Soft-failure detection: working-tick wall times feed an EWMA
        # monitor; a k-sigma outlier tick is a straggler (host contention,
        # background compile, a slow collective) — counted, and warned
        # about once so a degrading serving host leaves a signal even with
        # telemetry off.
        self.monitor = monitor or StragglerMonitor()
        self._straggler_warned = False

    def submit(self, req: Request) -> None:
        self.batcher.submit(req)

    def _feed_tokens(self) -> np.ndarray:
        toks = np.full((self.n_slots, 1), self.pad_id, np.int32)
        for i, slot in enumerate(self.batcher.slots):
            req = slot.request
            if req is None:
                continue
            if slot.prompt_cursor < len(req.prompt):
                toks[i, 0] = req.prompt[slot.prompt_cursor]
            elif req.output:
                toks[i, 0] = req.output[-1]
        return toks

    def tick(self) -> None:
        telem = telemetry.is_enabled()
        t0 = time.perf_counter()
        self.batcher.admit(budget=self.max_len - self._cursor)
        if telem:
            # Levels are recorded even for idle ticks (before the early
            # return) so the gauges reflect drained batches too.
            telemetry.gauge("serving.queue_depth").set(
                len(self.batcher.queue))
            telemetry.gauge("serving.active_slots").set(self.batcher.active)
        if self.batcher.active == 0:
            return
        toks = self._feed_tokens()
        # Shared-position stepping: all live slots write KV at the engine
        # cursor (the model's cur_len is a scalar).
        cur = self._cursor
        nxt, self.cache = self.step(self.params, jnp.asarray(toks),
                                    self.cache, jnp.int32(cur))
        nxt = np.asarray(nxt)
        for i, slot in enumerate(self.batcher.slots):
            req = slot.request
            if req is None:
                continue
            # Advance each slot's position individually: snapping to the
            # global max would jump mid-stream admissions to the deepest
            # slot's depth and make hit_cap retire fresh requests early.
            slot.pos += 1
            if slot.prompt_cursor < len(req.prompt):
                slot.prompt_cursor += 1
                if slot.prompt_cursor == len(req.prompt):
                    req.output.append(int(nxt[i]))  # first generated token
            else:
                req.output.append(int(nxt[i]))
        self._cursor += 1
        self.batcher.retire()
        if self.batcher.active == 0:
            self._cursor = 0  # batch drained: next wave reuses the cache
        self._tick += 1
        # Straggler accounting covers working ticks only — idle ticks
        # return above and would drown both the EWMA and the latency
        # distribution in no-op times.
        dt = time.perf_counter() - t0
        if self.monitor.observe(dt):
            if telem:
                telemetry.counter("serving.straggler_ticks").inc()
            if not self._straggler_warned:
                self._straggler_warned = True
                warnings.warn(
                    f"ServeEngine: tick {self._tick - 1} took {dt * 1e3:.1f} "
                    f"ms against an EWMA of {self.monitor.mean * 1e3:.1f} ms "
                    f"— straggling (further stragglers are counted under "
                    f"serving.straggler_ticks, not warned)",
                    StragglerTickWarning, stacklevel=2)
        if telem:
            telemetry.gauge("serving.tick_ewma_s").set(self.monitor.mean)
            telemetry.histogram("serving.tick_latency_s").observe(dt)

    def run_until_drained(self, max_ticks: int = 10_000) -> DrainResult:
        finished: DrainResult = DrainResult()
        ticks = 0
        for _ in range(max_ticks):
            before = [s.request for s in self.batcher.slots]
            self.tick()
            ticks += 1
            finished.extend(r for r in before
                            if r is not None and r.done and r not in finished)
            if not self.batcher.queue and self.batcher.active == 0:
                break
        # collect any stragglers: requests still queued, and oversize
        # rejections (popped from the queue at admission — sweeping only the
        # queue silently dropped them from the finished list).  Rejections
        # are drained, not copied: a reused engine must not re-surface them
        # (or leak them) on the next drain cycle.
        finished.extend(r for r in self.batcher.queue if r.done)
        finished.extend(r for r in self.batcher.rejected if r not in finished)
        self.batcher.rejected.clear()
        finished.ticks = ticks
        finished.pending_queued = sum(1 for r in self.batcher.queue
                                      if not r.done)
        finished.pending_active = self.batcher.active
        finished.drained = finished.pending == 0
        if not finished.drained:
            # Hitting the tick budget with live requests used to return
            # silently incomplete — surface it: the caller sees the status,
            # telemetry counts it, and a warning names the shortfall.
            if telemetry.is_enabled():
                telemetry.counter("serving.drain_exhausted").inc()
            warnings.warn(
                f"run_until_drained: tick budget {max_ticks} exhausted with "
                f"{finished.pending_queued} request(s) still queued and "
                f"{finished.pending_active} still active — returned list is "
                f"incomplete (result.drained is False)",
                DrainExhaustedWarning, stacklevel=2)
        return finished
