"""Fault-tolerant shape-bucketed CNN serving tier over ``CnnEngine``.

The kernels (PR 1-5) made sparse conv fast; telemetry and the pre-flight
verifier (PR 6-7) made "the fast path is unavailable" an observable,
classifiable state for a single forward.  This module lifts that discipline
to the request-serving layer, where Escoin's premise — sparse execution
wins only under the right conditions — meets heavy traffic:

  admission control      requests are routed to *shape buckets* (a fixed
                         (c, h, w, batch) each, padded up, one compiled
                         program per bucket x ladder rung — bounded compile
                         count); bounded per-bucket queues shed load with
                         machine-readable rejection reasons; per-request
                         deadlines shed work that could no longer be useful
  retry with backoff     a failing serve step is classified by the
                         *production* ``FailureDetector`` (shared with the
                         training loop): retryable faults re-enqueue their
                         requests under a deterministic capped-exponential
                         ``Backoff``; fatal faults reject with a reason;
                         repeated retryables escalate into degradation
  graceful degradation   each bucket owns an explicit plan ladder —
                         ``tuned`` (the autotuner's plan) -> ``quantised``
                         (the same plan with int8 value streams) ->
                         ``dense`` (the always-feasible baseline).  Every
                         rung is verified by the pre-flight checker at
                         build time (a rung whose plan would silently fall
                         back is *dropped*, not served); under overload or
                         escalating faults the bucket steps down a rung,
                         and steps back up after a cool-down of healthy
                         ticks.  The executed rung is recorded on every
                         forward's ``ExecutionReport`` and in telemetry.

Nothing here blocks on lost work: every submitted request terminates in
exactly one of completed-with-result or rejected-with-reason — the
invariant the seeded chaos harness (``repro.serving.chaos``) asserts under
injected plan corruption, schedule infeasibility, step faults, and
straggler ticks.

Time is injectable: ``VirtualClock`` drives deadlines, backoff, and
latency bookkeeping from the roofline cost of the executed rung (plus any
chaos inflation), so SLO tests and the benchmark's robustness section are
bit-deterministic; ``WallClock`` serves real time.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.engine import CnnEngine, Program, lower
from repro.runtime.fault_tolerance import (Backoff, FailureDetector,
                                           StragglerMonitor)
from repro.serving.chaos import ChaosInjector
from repro.tuning.cache import PlanCache, PlanEntry

# Machine-readable rejection reasons — every rejected request carries
# exactly one, and telemetry counts each under
# ``serving.cnn.rejected.<reason>``.
REJECT_REASONS = frozenset({
    "no_bucket",          # no configured bucket fits the request's shape
    "queue_full",         # bounded bucket queue at capacity (load shed)
    "deadline_expired",   # end-to-end deadline passed while queued
    "retries_exhausted",  # retryable faults exceeded max_attempts
    "fatal_error",        # serve step raised a non-retryable failure
    "drain_exhausted",    # server stopped (tick budget) before dispatch
})

# Ladder step reasons recorded on degradation/recovery events.
LADDER_REASONS = frozenset({
    "overload",          # queue above the high-water mark
    "escalate",          # FailureDetector strikes exhausted
    "preflight_failed",  # rung dropped at build: verifier errors/fallbacks
    "recovered",         # cool-down of healthy ticks passed: step back up
})


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """One admission bucket: requests of channel count ``c`` with spatial
    extent <= (h, w) are zero-padded up to exactly this shape and served
    in fixed batches of ``batch``."""

    c: int
    h: int
    w: int
    batch: int = 4

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.c, self.h, self.w)

    @property
    def key(self) -> str:
        return f"{self.c}x{self.h}x{self.w}b{self.batch}"


@dataclasses.dataclass
class InferenceRequest:
    """One CNN inference request.

    ``x`` is the input image (c, h, w); ``None`` serves zeros of ``shape``
    (synthetic traces).  ``deadline_s`` is the end-to-end budget relative
    to submission; expired requests are shed, not served late silently.
    """

    rid: int
    x: Optional[np.ndarray] = None
    shape: Optional[Tuple[int, int, int]] = None
    deadline_s: Optional[float] = None
    # filled by the server
    status: str = "new"            # new | queued | done | rejected
    reject_reason: Optional[str] = None
    attempts: int = 0              # serve attempts consumed so far
    submitted_s: float = 0.0
    not_before_s: float = 0.0      # backoff: earliest re-dispatch time
    deadline_abs_s: Optional[float] = None
    completed_s: Optional[float] = None
    result: Optional[np.ndarray] = None
    rung: Optional[str] = None     # ladder rung the result was computed at
    bucket: Optional[str] = None

    def __post_init__(self):
        if self.shape is None:
            if self.x is None:
                raise ValueError("request needs x or shape")
            self.shape = tuple(self.x.shape)

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed_s is None:
            return None
        return self.completed_s - self.submitted_s


@dataclasses.dataclass(frozen=True)
class LadderEvent:
    """One degradation-ladder transition (or build-time rung drop)."""

    t_s: float
    bucket: str
    from_rung: str
    to_rung: str
    reason: str                    # one of LADDER_REASONS

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class VirtualClock:
    """Deterministic clock: ticks advance by the executed rung's roofline
    cost (plus chaos inflation) instead of host wall time."""

    virtual = True

    def __init__(self, start_s: float = 0.0):
        self._t = start_s

    def now(self) -> float:
        return self._t

    def advance(self, dt_s: float) -> None:
        self._t += max(dt_s, 0.0)


class WallClock:
    """Real time.  ``advance`` sleeps (bounded) so idle waits make
    progress toward arrivals/backoff expiries without spinning."""

    virtual = False

    def now(self) -> float:
        return time.perf_counter()

    def advance(self, dt_s: float) -> None:
        if dt_s > 0:
            time.sleep(min(dt_s, 0.005))


@dataclasses.dataclass
class _Rung:
    """One verified rung of a bucket's degradation ladder."""

    name: str                       # tuned | quantised | dense
    plan: Dict[str, PlanEntry]
    report: Any                     # static ExecutionReport at this rung
    est_s: float                    # roofline batch-forward estimate


@dataclasses.dataclass
class _Bucket:
    spec: BucketSpec
    program: Program
    engine: CnnEngine
    rungs: List[_Rung]
    detector: FailureDetector
    rung_idx: int = 0
    healthy_ticks: int = 0
    queue: Deque[InferenceRequest] = dataclasses.field(
        default_factory=collections.deque)

    @property
    def rung(self) -> _Rung:
        return self.rungs[self.rung_idx]


@dataclasses.dataclass
class SloReport:
    """End-of-trace SLO summary: the robustness acceptance surface."""

    submitted: int = 0
    completed: int = 0
    rejected: Dict[str, int] = dataclasses.field(default_factory=dict)
    retries: int = 0
    deadline_misses: int = 0        # completed, but after their deadline
    straggler_ticks: int = 0
    ticks: int = 0
    p50_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    max_latency_s: float = 0.0
    degradations: List[LadderEvent] = dataclasses.field(default_factory=list)
    dropped_rungs: List[dict] = dataclasses.field(default_factory=list)
    rungs_executed: Dict[str, int] = dataclasses.field(default_factory=dict)
    duplicated: int = 0

    @property
    def rejected_total(self) -> int:
        return sum(self.rejected.values())

    @property
    def lost(self) -> int:
        return self.submitted - self.completed - self.rejected_total

    def verify(self) -> "SloReport":
        """Raise unless every request terminated exactly once."""
        if self.lost:
            raise AssertionError(
                f"{self.lost} request(s) lost: submitted={self.submitted} "
                f"completed={self.completed} rejected={self.rejected}")
        if self.duplicated:
            raise AssertionError(
                f"{self.duplicated} request(s) terminated more than once")
        return self

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["degradations"] = [e.to_dict() for e in self.degradations]
        d["rejected_total"] = self.rejected_total
        d["lost"] = self.lost
        return d

    def format(self) -> str:
        rej = ", ".join(f"{k}={v}" for k, v in sorted(self.rejected.items()))
        lines = [
            f"SLO: submitted={self.submitted} completed={self.completed} "
            f"rejected={self.rejected_total} ({rej or 'none'}) "
            f"lost={self.lost}",
            f"     retries={self.retries} deadline_misses="
            f"{self.deadline_misses} straggler_ticks={self.straggler_ticks} "
            f"ticks={self.ticks}",
            f"     latency p50={self.p50_latency_s * 1e3:.3f}ms "
            f"p99={self.p99_latency_s * 1e3:.3f}ms "
            f"max={self.max_latency_s * 1e3:.3f}ms",
            f"     rungs_executed={self.rungs_executed or '{}'} "
            f"degradations={len(self.degradations)} "
            f"dropped_rungs={len(self.dropped_rungs)}",
        ]
        for e in self.degradations:
            lines.append(f"     ladder t={e.t_s * 1e3:9.3f}ms {e.bucket}: "
                         f"{e.from_rung} -> {e.to_rung} ({e.reason})")
        return "\n".join(lines)


class RobustCnnServer:
    """Shape-bucketed, deadline-aware, degradation-laddered CNN serving.

    ``net`` is a layer-spec list (``repro.models.cnn`` vocabulary) and
    ``params`` its conv parameters (shared across buckets — conv weights
    are spatial-size-independent).  One engine + plan ladder is built per
    ``BucketSpec``; ``plan`` optionally overrides the autotuner (a
    ``{layer: PlanEntry}`` dict applied to every bucket, or a callable
    ``(program, batch) -> plan``), and ``plan_cache`` names a persistent
    plan-cache JSON consulted when autotuning (the chaos harness corrupts
    this file to exercise resilient loading).

    ``chaos`` (a :class:`~repro.serving.chaos.ChaosInjector`) injects
    faults at the documented seams; production deployments leave it None.
    """

    def __init__(self, net: Sequence[Any], params: Dict[str, Any],
                 buckets: Sequence[BucketSpec], *,
                 plan: Any = None,
                 plan_cache: Optional[str] = None,
                 queue_depth: int = 64,
                 max_attempts: int = 3,
                 backoff: Optional[Backoff] = None,
                 default_deadline_s: Optional[float] = None,
                 high_water: float = 0.75,
                 low_water: float = 0.25,
                 cooldown_ticks: int = 8,
                 max_strikes: int = 3,
                 min_tick_s: float = 1e-6,
                 clock: Any = None,
                 monitor: Optional[StragglerMonitor] = None,
                 chaos: Optional[ChaosInjector] = None):
        if not buckets:
            raise ValueError("need at least one BucketSpec")
        if not 0.0 <= low_water <= high_water <= 1.0:
            raise ValueError(
                f"water marks must satisfy 0 <= low ({low_water}) <= "
                f"high ({high_water}) <= 1")
        self.params = params
        self.queue_depth = queue_depth
        self.max_attempts = max_attempts
        self.backoff = backoff or Backoff()
        self.default_deadline_s = default_deadline_s
        self.high_water = high_water
        self.low_water = low_water
        self.cooldown_ticks = cooldown_ticks
        self.min_tick_s = min_tick_s
        self.clock = clock if clock is not None else WallClock()
        self.monitor = monitor or StragglerMonitor()
        self.chaos = chaos
        self.events: List[LadderEvent] = []
        self.dropped_rungs: List[dict] = []
        self.requests: List[InferenceRequest] = []
        self._terminal: Dict[int, int] = {}   # rid -> terminal transitions
        self._rungs_executed: Dict[str, int] = {}
        self._retries = 0
        self._straggler_ticks = 0
        self._ticks = 0
        self._buckets = [
            self._build_bucket(net, spec, plan, plan_cache, max_strikes)
            for spec in buckets]

    # -- construction ------------------------------------------------------

    def _build_bucket(self, net, spec: BucketSpec, plan, plan_cache: Optional[str],
                      max_strikes: int) -> _Bucket:
        program = lower(net, spec.shape)
        if callable(plan):
            base = plan(program, spec.batch)
        elif plan is not None:
            base = dict(plan)
        else:
            from repro.tuning.planner import plan_program
            cache = PlanCache(plan_cache) if plan_cache else None
            base = plan_program(program, batch=spec.batch, mode="roofline",
                                cache=cache, params=self.params)
        if self.chaos is not None:
            # Forced-schedule-infeasibility seam: the injector stales some
            # entries; the ladder build below must catch them statically.
            base = self.chaos.corrupt_plan(base, program)
        engine = CnnEngine(program, self.params, None)
        rungs = self._build_ladder(spec, program, engine, base)
        return _Bucket(spec=spec, program=program, engine=engine,
                       rungs=rungs,
                       detector=FailureDetector(max_strikes=max_strikes))

    def _ladder_plans(self, base: Dict[str, PlanEntry],
                      ) -> List[Tuple[str, Dict[str, PlanEntry]]]:
        """The rung candidates derived from one tuned plan: tuned ->
        quantised (int8 value streams on the sparse kernels — the engine
        quantises f32 banks in-trace) -> dense (always feasible)."""
        quant = {
            name: (dataclasses.replace(pe, value_dtype="int8",
                                       provenance="ladder")
                   if pe.method in ("pallas", "bsr")
                   and pe.value_dtype == "float32" else pe)
            for name, pe in base.items()}
        dense = {name: PlanEntry(method="dense", source=pe.source,
                                 provenance="ladder")
                 for name, pe in base.items()}
        out = [("tuned", base)]
        if quant != base:
            out.append(("quantised", quant))
        if dense != base:
            out.append(("dense", dense))
        return out

    def _build_ladder(self, spec: BucketSpec, program: Program,
                      engine: CnnEngine,
                      base: Dict[str, PlanEntry]) -> List[_Rung]:
        """Verify each candidate rung with the pre-flight checker and the
        engine's static dispatch report; a rung that would error or
        silently fall back is dropped (recorded), never served."""
        from repro.analysis.checker import preflight

        shape = (spec.batch,) + spec.shape
        rungs: List[_Rung] = []
        for name, plan in self._ladder_plans(base):
            diags = preflight(program, plan, self.params, batch=spec.batch)
            errors = [d for d in diags if d.severity == "error"]
            report = engine.execution_report(shape, "auto",
                                             plan_override=plan, rung=name)
            if errors or report.fallback_count:
                drop = {
                    "bucket": spec.key, "rung": name,
                    "preflight_errors": [d.rule for d in errors],
                    "fallback_reasons": [o.fallback_reason
                                         for o in report.fallback_ops],
                }
                self.dropped_rungs.append(drop)
                if telemetry.is_enabled():
                    telemetry.counter("serving.cnn.ladder.dropped_rungs").inc()
                continue
            rungs.append(_Rung(name=name, plan=plan, report=report,
                               est_s=max(report.est_s, self.min_tick_s)))
        if not rungs:
            # The dense rung is feasibility-free; reaching here means the
            # program itself fails verification — a config bug, not a
            # runtime state to degrade through.
            raise RuntimeError(
                f"bucket {spec.key}: no ladder rung passed pre-flight "
                f"verification ({self.dropped_rungs})")
        return rungs

    # -- admission ---------------------------------------------------------

    def _bucket_for(self, shape: Tuple[int, int, int]) -> Optional[_Bucket]:
        c, h, w = shape
        fits = [b for b in self._buckets
                if b.spec.c == c and b.spec.h >= h and b.spec.w >= w]
        if not fits:
            return None
        return min(fits, key=lambda b: b.spec.h * b.spec.w)

    def submit(self, req: InferenceRequest) -> bool:
        """Admit one request; returns False when it was rejected (shed) at
        admission — the request still terminates with a reason."""
        now = self.clock.now()
        req.submitted_s = now
        req.not_before_s = now
        if req.deadline_s is None:
            req.deadline_s = self.default_deadline_s
        if req.deadline_s is not None:
            req.deadline_abs_s = now + req.deadline_s
        self.requests.append(req)
        if telemetry.is_enabled():
            telemetry.counter("serving.cnn.submitted").inc()
        bucket = self._bucket_for(req.shape)
        if bucket is None:
            self._reject(req, "no_bucket")
            return False
        if len(bucket.queue) >= self.queue_depth:
            self._reject(req, "queue_full")
            return False
        req.status = "queued"
        req.bucket = bucket.spec.key
        bucket.queue.append(req)
        if telemetry.is_enabled():
            telemetry.counter("serving.cnn.admitted").inc()
            telemetry.gauge(
                f"serving.cnn.queue_depth.{bucket.spec.key}").set(
                    len(bucket.queue))
        return True

    # -- terminal transitions ---------------------------------------------

    def _terminate(self, req: InferenceRequest) -> None:
        self._terminal[req.rid] = self._terminal.get(req.rid, 0) + 1

    def _reject(self, req: InferenceRequest, reason: str) -> None:
        if reason not in REJECT_REASONS:
            raise ValueError(f"unknown rejection reason {reason!r}")
        req.status = "rejected"
        req.reject_reason = reason
        self._terminate(req)
        if telemetry.is_enabled():
            telemetry.counter("serving.cnn.rejected").inc()
            telemetry.counter(f"serving.cnn.rejected.{reason}").inc()

    def _complete(self, req: InferenceRequest, y: np.ndarray,
                  rung: str) -> None:
        now = self.clock.now()
        req.status = "done"
        req.result = y
        req.rung = rung
        req.completed_s = now
        self._terminate(req)
        if telemetry.is_enabled():
            telemetry.counter("serving.cnn.completed").inc()
            telemetry.histogram("serving.cnn.latency_s").observe(
                req.latency_s)

    # -- the degradation ladder -------------------------------------------

    def _step_down(self, bucket: _Bucket, reason: str) -> bool:
        if bucket.rung_idx >= len(bucket.rungs) - 1:
            return False
        frm = bucket.rung.name
        bucket.rung_idx += 1
        bucket.healthy_ticks = 0
        self._ladder_event(bucket, frm, bucket.rung.name, reason)
        if telemetry.is_enabled():
            telemetry.counter("serving.cnn.ladder.step_down").inc()
        return True

    def _step_up(self, bucket: _Bucket) -> bool:
        if bucket.rung_idx == 0:
            return False
        frm = bucket.rung.name
        bucket.rung_idx -= 1
        bucket.healthy_ticks = 0
        self._ladder_event(bucket, frm, bucket.rung.name, "recovered")
        if telemetry.is_enabled():
            telemetry.counter("serving.cnn.ladder.step_up").inc()
        return True

    def _ladder_event(self, bucket: _Bucket, frm: str, to: str,
                      reason: str) -> None:
        if reason not in LADDER_REASONS:
            raise ValueError(f"unknown ladder reason {reason!r}")
        self.events.append(LadderEvent(
            t_s=self.clock.now(), bucket=bucket.spec.key, from_rung=frm,
            to_rung=to, reason=reason))
        if telemetry.is_enabled():
            telemetry.gauge(f"serving.cnn.rung.{bucket.spec.key}").set(
                bucket.rung_idx)

    # -- the serve loop ----------------------------------------------------

    def _shed_expired(self, bucket: _Bucket) -> None:
        now = self.clock.now()
        keep: Deque[InferenceRequest] = collections.deque()
        for req in bucket.queue:
            if req.deadline_abs_s is not None and now >= req.deadline_abs_s:
                self._reject(req, "deadline_expired")
            else:
                keep.append(req)
        bucket.queue = keep

    def _eligible(self, bucket: _Bucket) -> List[InferenceRequest]:
        """Up to ``batch`` queued requests whose backoff has expired,
        FIFO order preserved for the rest."""
        now = self.clock.now()
        take: List[InferenceRequest] = []
        keep: Deque[InferenceRequest] = collections.deque()
        for req in bucket.queue:
            if len(take) < bucket.spec.batch and req.not_before_s <= now:
                take.append(req)
            else:
                keep.append(req)
        bucket.queue = keep
        return take

    def _batch_input(self, bucket: _Bucket,
                     reqs: List[InferenceRequest]) -> jnp.ndarray:
        spec = bucket.spec
        x = np.zeros((spec.batch,) + spec.shape, np.float32)
        for i, req in enumerate(reqs):
            if req.x is not None:
                c, h, w = req.x.shape
                x[i, :c, :h, :w] = req.x  # pad up into the bucket shape
        return jnp.asarray(x)

    def _dispatch(self, bucket: _Bucket,
                  reqs: List[InferenceRequest]) -> None:
        """One serve step: run the batch at the bucket's current rung;
        classify any failure through the production detector."""
        rung = bucket.rung
        try:
            if self.chaos is not None:
                exc = self.chaos.draw_step_fault()
                if exc is not None:
                    raise exc
            y = np.asarray(bucket.engine(
                self._batch_input(bucket, reqs), "auto",
                plan_override=rung.plan, rung=rung.name))
        except Exception as exc:  # noqa: BLE001 - classified below
            self._on_step_failure(bucket, reqs, exc)
            return
        bucket.detector.reset()
        for i, req in enumerate(reqs):
            self._complete(req, y[i], rung.name)
        self._rungs_executed[rung.name] = (
            self._rungs_executed.get(rung.name, 0) + 1)
        if telemetry.is_enabled():
            telemetry.counter(f"serving.cnn.rung_ticks.{rung.name}").inc()

    def _on_step_failure(self, bucket: _Bucket,
                         reqs: List[InferenceRequest],
                         exc: BaseException) -> None:
        verdict = bucket.detector.record(exc)
        if verdict == "fatal":
            for req in reqs:
                self._reject(req, "fatal_error")
            return
        if verdict == "escalate":
            # Repeated retryable faults: the rung is suspect — degrade and
            # give the batch a fresh start on the next rung down.
            self._step_down(bucket, "escalate")
            bucket.detector.reset()
        now = self.clock.now()
        for req in reqs:
            req.attempts += 1
            if req.attempts >= self.max_attempts:
                self._reject(req, "retries_exhausted")
                continue
            req.not_before_s = now + self.backoff.delay_s(req.attempts - 1)
            bucket.queue.appendleft(req)
            self._retries += 1
            if telemetry.is_enabled():
                telemetry.counter("serving.cnn.retries").inc()

    def tick(self) -> int:
        """One scheduling round over every bucket; returns the number of
        requests dispatched (0: nothing was eligible)."""
        dispatched = 0
        telem = telemetry.is_enabled()
        for bucket in self._buckets:
            self._shed_expired(bucket)
            if (len(bucket.queue) >= self.high_water * self.queue_depth
                    and bucket.queue):
                self._step_down(bucket, "overload")
            reqs = self._eligible(bucket)
            if telem:
                telemetry.gauge(
                    f"serving.cnn.queue_depth.{bucket.spec.key}").set(
                        len(bucket.queue) + len(reqs))
            if not reqs:
                continue
            # Tick duration: roofline cost of the dispatched rung under a
            # virtual clock (deterministic), measured wall otherwise —
            # either way subject to chaos straggler inflation and observed
            # by the EWMA monitor.  The straggle draw happens before the
            # dispatch, and a virtual clock advances past the batch cost
            # before completion bookkeeping, so request latencies include
            # (possibly inflated) execution time deterministically.
            t0 = time.perf_counter()
            dt = bucket.rung.est_s
            straggled = False
            if self.chaos is not None:
                dt, straggled = self.chaos.inflate_tick(dt)
            if self.clock.virtual:
                self.clock.advance(dt)
            self._dispatch(bucket, reqs)
            dispatched += len(reqs)
            self._ticks += 1
            if not self.clock.virtual:
                dt = time.perf_counter() - t0
                if straggled:
                    dt *= self.chaos.cfg.straggler_factor
                    time.sleep(min(dt, 0.01))
            if self.monitor.observe(dt):
                self._straggler_ticks += 1
                if telem:
                    telemetry.counter("serving.cnn.straggler_ticks").inc()
            if telem:
                telemetry.gauge("serving.cnn.tick_ewma_s").set(
                    self.monitor.mean)
                telemetry.histogram("serving.cnn.tick_latency_s").observe(dt)
            # Recovery bookkeeping: a dispatched tick with no strikes and a
            # calm queue is healthy; enough of them steps the ladder up.
            if (bucket.detector.strikes == 0
                    and len(bucket.queue) <= self.low_water
                    * self.queue_depth):
                bucket.healthy_ticks += 1
                if (bucket.healthy_ticks >= self.cooldown_ticks
                        and bucket.rung_idx > 0):
                    self._step_up(bucket)
            else:
                bucket.healthy_ticks = 0
        return dispatched

    # -- traces ------------------------------------------------------------

    def pending(self) -> int:
        return sum(len(b.queue) for b in self._buckets)

    def run_trace(self, arrivals: Sequence[Any], *,
                  request_factory: Optional[Callable[[Any], InferenceRequest]]
                  = None, max_ticks: int = 100_000) -> SloReport:
        """Serve a seeded arrival trace (``repro.serving.chaos
        .arrival_trace``) to completion and return the SLO summary.

        Arrivals are submitted when the clock reaches their ``t_s``; idle
        rounds advance a virtual clock to the next actionable instant
        (arrival or backoff expiry) instead of spinning.  Requests still
        queued when the tick budget runs out are rejected with
        ``drain_exhausted`` — stopping the server must not lose requests.
        """
        make = request_factory or (lambda a: InferenceRequest(
            rid=a.rid, shape=a.shape, deadline_s=a.deadline_s))
        todo = sorted(arrivals, key=lambda a: a.t_s)
        i = 0
        ticks = 0
        while ticks < max_ticks:
            now = self.clock.now()
            while i < len(todo) and todo[i].t_s <= now:
                self.submit(make(todo[i]))
                i += 1
            if i == len(todo) and self.pending() == 0:
                break
            n = self.tick()
            ticks += 1
            if n == 0:
                # Nothing eligible: jump to the next actionable instant.
                horizon = [a.t_s for a in todo[i:i + 1]]
                horizon += [r.not_before_s
                            for b in self._buckets for r in b.queue]
                if not horizon:
                    break
                self.clock.advance(max(min(horizon) - now, self.min_tick_s))
        for bucket in self._buckets:
            while bucket.queue:
                self._reject(bucket.queue.popleft(), "drain_exhausted")
        return self.slo_report()

    def slo_report(self) -> SloReport:
        rep = SloReport()
        rep.submitted = len(self.requests)
        lat: List[float] = []
        for req in self.requests:
            if req.status == "done":
                rep.completed += 1
                lat.append(req.latency_s)
                if (req.deadline_abs_s is not None
                        and req.completed_s > req.deadline_abs_s):
                    rep.deadline_misses += 1
            elif req.status == "rejected":
                rep.rejected[req.reject_reason] = (
                    rep.rejected.get(req.reject_reason, 0) + 1)
        rep.retries = self._retries
        rep.straggler_ticks = self._straggler_ticks
        rep.ticks = self._ticks
        rep.degradations = list(self.events)
        rep.dropped_rungs = list(self.dropped_rungs)
        rep.rungs_executed = dict(self._rungs_executed)
        rep.duplicated = sum(1 for n in self._terminal.values() if n > 1)
        if lat:
            xs = sorted(lat)
            rep.p50_latency_s = xs[min(len(xs) - 1, int(0.50 * len(xs)))]
            rep.p99_latency_s = xs[min(len(xs) - 1, int(0.99 * len(xs)))]
            rep.max_latency_s = xs[-1]
        return rep
