"""Deterministic, seeded fault injection for the CNN serving tier.

Production robustness claims ("zero lost requests under faults", "the
degradation ladder activates and recovers", "p99 stays bounded") are only
testable if faults are *reproducible*.  This module injects failures at the
seams the execution stack already treats as first-class states — never by
monkeypatching internals — so every chaos run is an ordinary run of
production code under adverse, replayable inputs:

  plan-cache corruption      ``corrupt_plan_cache_file`` mangles the JSON
                             document on disk; ``PlanCache.load`` (PR 7)
                             degrades to an empty cache with a
                             ``PlanCacheWarning`` and the planner re-tunes
  forced schedule            ``ChaosInjector.corrupt_plan`` pins a
  infeasibility              non-dividing ``tm`` on pallas entries — the
                             exact ``nondividing_tm`` state the kernels'
                             ``resolve_schedule`` probes and the pre-flight
                             verifier both classify; the serving ladder
                             drops the rung instead of silently running
                             the dense-reconstruction fallback
  serve-step faults          ``draw_step_fault`` raises retryable
                             (``ChaosRetryableError`` — message carries a
                             ``RETRYABLE_MARKERS`` token so the *production*
                             ``FailureDetector`` classifies it) or fatal
                             (``ChaosFatalError``) exceptions inside the
                             serve step
  straggler ticks            ``inflate_tick`` multiplies a tick's duration
                             so ``StragglerMonitor`` flags it (virtual-clock
                             runs stay fully deterministic; wall-clock runs
                             sleep the excess)

All draws come from one ``numpy`` Generator seeded by ``ChaosConfig.seed``:
the same config and workload replay the same fault sequence, tick for tick.

The module also hosts the synthetic-workload helpers shared by the tests,
the chaos-smoke CI job, and the benchmark's robustness section:
``slice_net`` (a reduced 3-conv slice of each paper network — interpret-mode
Pallas stays tractable on CPU) and ``arrival_trace`` (a seeded
heavy-traffic arrival process).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

# Retryable messages must trip repro.runtime.fault_tolerance.RETRYABLE_MARKERS
# ("UNAVAILABLE") — chaos faults are classified by the production detector,
# not by a chaos-aware special case.
_RETRYABLE_MSG = "UNAVAILABLE: injected transient collective fault (chaos)"
_FATAL_MSG = "injected device loss (chaos): host dropped from the mesh"


class ChaosRetryableError(RuntimeError):
    """An injected transient fault (classified retryable by message)."""


class ChaosFatalError(RuntimeError):
    """An injected hard failure (classified fatal: no retryable marker)."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Injection rates (per opportunity) + the seed that makes them replay.

    Rates are independent Bernoulli draws: ``step_fault_rate`` /
    ``fatal_fault_rate`` per dispatched batch (retryable is drawn first),
    ``plan_corruption_rate`` per tuned pallas plan entry,
    ``straggler_rate`` per tick.  ``straggler_factor`` multiplies a
    straggling tick's duration.
    """

    seed: int = 0
    step_fault_rate: float = 0.0
    fatal_fault_rate: float = 0.0
    plan_corruption_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_factor: float = 8.0

    def __post_init__(self):
        for f in ("step_fault_rate", "fatal_fault_rate",
                  "plan_corruption_rate", "straggler_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f}={v} outside [0, 1]")
        if self.straggler_factor < 1.0:
            raise ValueError(
                f"straggler_factor={self.straggler_factor} below 1")


class ChaosInjector:
    """Draws faults from one seeded stream at the serving tier's seams."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.injected_step_faults = 0
        self.injected_fatal_faults = 0
        self.injected_stragglers = 0
        self.corrupted_entries: List[str] = []

    # -- serve-step faults -------------------------------------------------

    def draw_step_fault(self) -> Optional[Exception]:
        """One per-batch draw: a retryable or fatal exception, or None.

        The caller raises the returned exception *inside* its serve step so
        the production retry/rejection machinery handles it.
        """
        if (self.cfg.step_fault_rate
                and self.rng.random() < self.cfg.step_fault_rate):
            self.injected_step_faults += 1
            return ChaosRetryableError(_RETRYABLE_MSG)
        if (self.cfg.fatal_fault_rate
                and self.rng.random() < self.cfg.fatal_fault_rate):
            self.injected_fatal_faults += 1
            return ChaosFatalError(_FATAL_MSG)
        return None

    # -- straggler ticks ---------------------------------------------------

    def inflate_tick(self, dt: float) -> Tuple[float, bool]:
        """Maybe stretch one tick's duration; returns (dt', straggled)."""
        if (self.cfg.straggler_rate
                and self.rng.random() < self.cfg.straggler_rate):
            self.injected_stragglers += 1
            return dt * self.cfg.straggler_factor, True
        return dt, False

    # -- forced schedule infeasibility ------------------------------------

    def corrupt_plan(self, plan, program):
        """Pin a non-dividing ``tm`` on pallas entries at the configured
        rate — the stale-plan state ``resolve_schedule`` reports as
        ``nondividing_tm`` and the pre-flight verifier flags as an error.

        ``m - 1`` never divides ``m`` for ``m > 2``, so the corruption is
        guaranteed infeasible (layers with ``m <= 2`` are skipped).
        Returns a new plan dict; the input is not mutated.
        """
        out = dict(plan)
        for op in program.conv_ops:
            pe = out.get(op.name)
            if (pe is None or pe.method != "pallas" or op.m <= 2
                    or not self.cfg.plan_corruption_rate):
                continue
            if self.rng.random() < self.cfg.plan_corruption_rate:
                out[op.name] = dataclasses.replace(pe, tm=op.m - 1)
                self.corrupted_entries.append(op.name)
        return out

    def summary(self) -> dict:
        return {"seed": self.cfg.seed,
                "step_faults": self.injected_step_faults,
                "fatal_faults": self.injected_fatal_faults,
                "stragglers": self.injected_stragglers,
                "corrupted_entries": list(self.corrupted_entries)}


def corrupt_plan_cache_file(path: str, *, mode: str = "garbage") -> None:
    """Mangle a plan-cache document on disk (the plan-load seam).

    ``garbage`` overwrites with non-JSON bytes, ``truncate`` cuts the file
    mid-document, ``bad_entry`` drops a required field from one entry —
    each a corruption ``PlanCache.load`` must degrade through (empty or
    reduced cache + ``PlanCacheWarning``), never crash on.
    """
    if mode == "garbage":
        with open(path, "w") as fh:
            fh.write("\x00not json {{{")
        return
    with open(path) as fh:
        text = fh.read()
    if mode == "truncate":
        with open(path, "w") as fh:
            fh.write(text[: max(1, len(text) // 2)])
        return
    if mode == "bad_entry":
        doc = json.loads(text)
        for key, entry in doc.get("entries", {}).items():
            entry.pop("method", None)
            break
        with open(path, "w") as fh:
            json.dump(doc, fh)
        return
    raise ValueError(f"unknown corruption mode {mode!r}")


# --------------------------------------------------------------------------
# synthetic workloads (shared by tests, CI chaos-smoke, and the benchmark)
# --------------------------------------------------------------------------

def slice_net(name: str, *, image: int = 12) -> List[Any]:
    """A reduced slice of one paper network: the first dense-kept conv plus
    the first two sparse convs, channels cut ~8x, stride forced to 1 — the
    same reduction ``launch/serve.py``'s autotune numeric check uses, so
    interpret-mode Pallas serves it tractably on CPU.  ``image`` is the
    native input the slice is sized for (buckets may pad above it)."""
    from repro.engine import lower
    from repro.models import cnn

    program = lower(cnn.NETWORKS[name](), (3, 224, 224))
    convs = [l for l, _ in program.conv_table]
    picked = ([next(l for l in convs if l.sparsity == 0)]
              + [l for l in convs if l.sparsity > 0][:2])
    net: List[Any] = []
    for l in picked:
        net.append(dataclasses.replace(
            l, out_c=max(8, min(32, l.out_c // 8)), stride=1))
        net.append(cnn.Relu())
    return net


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One synthetic request arrival."""

    rid: int
    t_s: float                 # arrival time (seconds from trace start)
    shape: Tuple[int, int, int]  # (c, h, w)
    deadline_s: Optional[float]  # end-to-end budget from arrival, or None


def arrival_trace(n: int, shapes: Sequence[Tuple[int, int, int]], *,
                  seed: int = 0, mean_gap_s: float = 0.002,
                  deadline_s: Optional[Tuple[float, float]] = (0.05, 0.5),
                  ) -> List[Arrival]:
    """A seeded heavy-traffic trace: exponential inter-arrivals
    (``mean_gap_s``), shapes drawn uniformly from ``shapes``, per-request
    deadlines uniform in ``deadline_s`` (None: no deadlines)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Arrival] = []
    for rid in range(n):
        t += float(rng.exponential(mean_gap_s))
        shape = shapes[int(rng.integers(len(shapes)))]
        dl = (float(rng.uniform(*deadline_s))
              if deadline_s is not None else None)
        out.append(Arrival(rid=rid, t_s=t, shape=tuple(shape), deadline_s=dl))
    return out
