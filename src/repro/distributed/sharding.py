"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Params and activations are annotated with *logical* names; a rules table maps
them to physical mesh axes at launch time.  This keeps every model definition
mesh-agnostic and makes resharding experiments (§Perf hillclimbs) one-line
changes.

Logical names:
  fsdp  -- parameter / optimizer-state sharding (ZeRO-3) axis
  tp    -- tensor parallel axis (heads, d_ff columns, experts, vocab)
  dp    -- activation batch axis (pure data parallel, incl. the pod axis)
  sp    -- sequence parallel axis for long-context activations
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


def default_rules(mesh: Mesh) -> Dict[str, Axis]:
    """DP over (pod, data); FSDP over data only (keeps ZeRO gathers on the
    fast in-pod ICI, cross-pod stays pure gradient DP over DCN); TP/SP/EP over
    model."""
    has_pod = "pod" in mesh.axis_names
    return {
        "fsdp": "data",
        "tp": "model",
        "dp": ("pod", "data") if has_pod else ("data",),
        "sp": "model",
    }


def set_rules(rules: Optional[Dict[str, Axis]], mesh: Optional[Mesh] = None) -> None:
    _STATE.rules = rules
    _STATE.mesh = mesh


def get_rules() -> Optional[Dict[str, Axis]]:
    return getattr(_STATE, "rules", None)


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


class use_rules:
    """Context manager: activate a rules table (and mesh) for tracing."""

    def __init__(self, rules: Optional[Dict[str, Axis]], mesh: Optional[Mesh] = None):
        self.rules, self.mesh = rules, mesh

    def __enter__(self):
        self.prev = (get_rules(), get_mesh())
        set_rules(self.rules, self.mesh)
        return self

    def __exit__(self, *exc):
        set_rules(*self.prev)
        return False


def resolve(spec: P) -> P:
    """Map a logical PartitionSpec to physical mesh axes.

    Unknown names map to None (replicated); tuples of names flatten.  A mesh
    axis may appear at most once per spec — when two logical names map to the
    same physical axis (e.g. serving rules with fsdp -> model), the first
    position keeps it and later positions drop to None.
    """
    rules = get_rules() or {}
    used: set = set()

    def one(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            out = []
            for e in entry:
                r = one(e)
                if isinstance(r, tuple):
                    out.extend(r)
                elif r is not None:
                    out.append(r)
            return tuple(out) if out else None
        r = rules.get(entry, entry if entry in _mesh_axes() else None)
        if r is None:
            return None
        axes = r if isinstance(r, tuple) else (r,)
        kept = tuple(a for a in axes if a not in used)
        used.update(kept)
        if not kept:
            return None
        return kept if isinstance(r, tuple) else kept[0]

    return P(*(one(e) for e in spec))


def _mesh_axes() -> Sequence[str]:
    mesh = get_mesh()
    return mesh.axis_names if mesh is not None else ()


def constrain(x: jax.Array, *names: Axis) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a mesh run."""
    mesh = get_mesh()
    if mesh is None or get_rules() is None:
        return x
    spec = resolve(P(*names))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(spec: P) -> Optional[NamedSharding]:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(spec))
