"""Machine-readable fallback reason codes + the always-on one-time warning.

Every silent-degradation branch in the execution stack reports through
:func:`record_fallback` with a reason code from :data:`REASONS`:

  smem_infeasible       the kernel's scalar-prefetched operands (packed ELL
                        indices / BCSR block-column table) bust the SMEM
                        budget — the layer can never run this kernel
  no_feasible_tiling    no VMEM-feasible output tiling exists (or the
                        plan-pinned tiling busts the budget at this
                        geometry)
  nondividing_tm        a pinned output-channel tile does not divide M
                        (typically a stale plan applied to a resized layer)
  stale_plan_no_block   a plan entry claims ``method="bsr"`` but carries no
                        BCSR block shape (pre-v5 cache document) — the
                        engine runs the dense executor instead
  value_dtype_mismatch  the plan's pinned value-storage dtype disagrees with
                        the already-quantised bank the params carry (e.g. a
                        migrated pre-v6 f32 entry against an int8 bank, or
                        an int8 entry against an fp8 bank) — the engine
                        runs the dense executor rather than silently
                        dequantising/requantising a bank the plan was not
                        scored against

Two consumers, with different lifetimes:

  * a **one-time ``warnings.warn``** (:class:`SparseFallbackWarning`, keyed
    per (kernel, layer-or-geometry, reason)) that fires regardless of
    whether telemetry is enabled — a mis-tuned or stale plan silently
    running the dense-reconstruction path must leave *some* signal;
  * **metrics counters** (``fallback.<kernel>.<reason>`` plus the roll-up
    ``fallback.total``), recorded only when telemetry is enabled.

Callers sit at trace/dispatch time (the feasibility checks are static
Python over shapes), so recording here never puts a host callback inside a
compiled program.
"""
from __future__ import annotations

import warnings
from typing import Optional, Set, Tuple

REASONS = frozenset({
    "smem_infeasible",
    "no_feasible_tiling",
    "nondividing_tm",
    "stale_plan_no_block",
    "value_dtype_mismatch",
})


class SparseFallbackWarning(UserWarning):
    """A sparse conv kernel silently took a fallback execution path."""


# (kernel, layer-or-geometry, reason) triples already warned about.
_WARNED: Set[Tuple[str, str, str]] = set()


def record_fallback(kernel: str, reason: str, *, layer: Optional[str] = None,
                    geometry: str = "", fallback_to: str = "") -> None:
    """Report one fallback decision: warn once per (layer, reason), and
    count it when telemetry is enabled.

    ``kernel`` names the reporting site (``sparse_conv`` / ``bsr_conv`` /
    ``engine``); ``layer`` the conv layer when the caller knows it (the
    geometry string keys the warning otherwise); ``fallback_to`` the path
    actually executed (``csr-direct``, ``dense``, ...).
    """
    if reason not in REASONS:
        raise ValueError(f"unknown fallback reason {reason!r}; "
                         f"one of {sorted(REASONS)}")
    key = (kernel, layer or geometry, reason)
    if key not in _WARNED:
        _WARNED.add(key)
        where = f"layer {layer!r}" if layer else "layer"
        tail = f" -> {fallback_to}" if fallback_to else ""
        warnings.warn(
            f"{kernel}: {where} ({geometry}) fell back{tail}: {reason}",
            SparseFallbackWarning, stacklevel=2)
    from repro import telemetry  # local: telemetry imports this module
    if telemetry.is_enabled():
        from repro.telemetry import metrics
        metrics.counter(f"fallback.{kernel}.{reason}").inc()
        metrics.counter("fallback.total").inc()


def reset_warnings() -> None:
    """Forget which (kernel, layer, reason) triples already warned (tests)."""
    _WARNED.clear()
