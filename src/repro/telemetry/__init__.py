"""Execution telemetry: metrics, traces, fallback reporting, reports.

Lightweight and dependency-free (stdlib-only at module level — no jax/numpy
imports) so every layer of the stack can import it without cycles:

  metrics   -- process-global registry (counters, gauges, p50/p95/p99
               histograms), ``snapshot()`` exports one JSON-able dict
  trace     -- span/event tracer exporting Chrome-trace-format JSON
               (chrome://tracing, Perfetto) + ``validate_chrome_trace``
  fallback  -- machine-readable fallback reason codes, one-time
               ``SparseFallbackWarning`` (always on), gated counters
  report    -- per-forward ``ExecutionReport``/``OpReport`` built by
               ``CnnEngine`` at dispatch time

The subsystem is **off by default** and zero-overhead when off: every
instrumentation site guards on :func:`is_enabled` — a single module-level
flag read — and nothing records from inside ``jax.jit``-traced code (all
sites sit at dispatch/trace time).  The one always-on signal is the
one-time fallback warning (see ``fallback.py``), which the issue requires
independent of telemetry state.
"""
from __future__ import annotations

import contextlib

from repro.telemetry import metrics
from repro.telemetry.fallback import (REASONS, SparseFallbackWarning,
                                      record_fallback, reset_warnings)
from repro.telemetry.metrics import (REGISTRY, counter, gauge, histogram,
                                     snapshot)
from repro.telemetry.report import ExecutionReport, OpReport
from repro.telemetry.trace import (TID_ROOFLINE, TID_WALL, Tracer,
                                   validate_chrome_trace)

__all__ = [
    "REASONS", "REGISTRY", "SparseFallbackWarning", "TID_ROOFLINE",
    "TID_WALL", "Tracer", "ExecutionReport", "OpReport", "counter",
    "disable", "enable", "enabled", "gauge", "get_tracer", "histogram",
    "is_enabled", "record_fallback", "reset", "reset_warnings", "snapshot",
    "validate_chrome_trace",
]

_ENABLED = False
_TRACER = Tracer()


def is_enabled() -> bool:
    """The single flag every instrumentation site checks."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


@contextlib.contextmanager
def enabled():
    """Enable telemetry for the duration of a ``with`` block."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = True
    try:
        yield
    finally:
        _ENABLED = prev


def get_tracer() -> Tracer:
    """The process-global tracer (`--trace` exports it)."""
    return _TRACER


def reset() -> None:
    """Clear metrics, trace events, and fallback-warning dedup (tests)."""
    metrics.reset()
    _TRACER.clear()
    reset_warnings()
