"""Structured span/event tracer with Chrome-trace-format JSON export.

Spans collect into an in-memory event list and export as the Chrome trace
event format (the ``{"traceEvents": [...]}`` JSON that chrome://tracing and
Perfetto load): complete events (``ph="X"``) for spans with a duration,
instant events (``ph="i"``) for point markers, and metadata events
(``ph="M"``) naming the lanes.  Timestamps are microseconds relative to the
tracer's first event, taken from ``time.perf_counter`` — a monotonic clock,
so spans never go backwards.

Two kinds of spans share the timeline on separate lanes (``tid``):

  wall      -- real measured durations (dispatch wrappers, timed-mode op
               segmentation, serving ticks)
  roofline  -- analytic per-op durations from an ExecutionReport: the
               engine's default (untimed) mode cannot time ops inside one
               compiled program, so it lays the roofline-attributed
               estimates out sequentially instead, tagged
               ``args.estimated = true``

:func:`validate_chrome_trace` checks an exported document against the
schema the tools require; CI runs it on a traced forward so a malformed
export fails the build instead of failing to load in Perfetto.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, List, Optional

# Lane ids (Chrome trace "tid"): one per span kind.
TID_WALL = 0
TID_ROOFLINE = 1

_THREAD_NAMES = {TID_WALL: "wall", TID_ROOFLINE: "roofline (estimated)"}


class Tracer:
    """Collects span/instant events; exports Chrome-trace JSON."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._t0: Optional[float] = None

    # -- clock ------------------------------------------------------------

    def _rel_us(self, t_s: Optional[float] = None) -> float:
        """Microseconds since the tracer's first event."""
        t_s = time.perf_counter() if t_s is None else t_s
        if self._t0 is None:
            self._t0 = t_s
        return (t_s - self._t0) * 1e6

    # -- recording --------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "op", tid: int = TID_WALL,
             **args: Any):
        """Context manager recording one complete ("X") event."""
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            t1 = time.perf_counter()
            self.complete(name, start_s=t0, dur_s=t1 - t0, cat=cat,
                          tid=tid, args=args)

    def complete(self, name: str, *, start_s: Optional[float] = None,
                 dur_s: float, cat: str = "op", tid: int = TID_WALL,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete event with an explicit duration.

        ``start_s`` is in the ``time.perf_counter`` domain (defaults to
        now); ``dur_s`` may be a measured wall time or an analytic
        estimate (tag the latter via ``args={"estimated": True}``).
        """
        self.events.append({
            "name": str(name), "cat": cat, "ph": "X",
            "ts": self._rel_us(start_s), "dur": max(0.0, dur_s) * 1e6,
            "pid": 0, "tid": tid, "args": dict(args or {}),
        })

    def instant(self, name: str, cat: str = "event", tid: int = TID_WALL,
                **args: Any) -> None:
        self.events.append({
            "name": str(name), "cat": cat, "ph": "i", "s": "t",
            "ts": self._rel_us(), "pid": 0, "tid": tid,
            "args": dict(args or {}),
        })

    # -- export -----------------------------------------------------------

    def to_chrome_trace(self) -> Dict[str, Any]:
        """The Chrome trace event format document (JSON Object Format)."""
        meta = [{
            "name": "thread_name", "ph": "M", "ts": 0.0, "pid": 0,
            "tid": tid, "args": {"name": label},
        } for tid, label in sorted(_THREAD_NAMES.items())]
        return {"traceEvents": meta + list(self.events),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        doc = self.to_chrome_trace()
        validate_chrome_trace(doc)
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1)
        return path

    def clear(self) -> None:
        self.events.clear()
        self._t0 = None

    def __len__(self) -> int:
        return len(self.events)


def validate_chrome_trace(doc: Any) -> None:
    """Raise ``ValueError`` unless ``doc`` is a loadable Chrome-trace JSON
    object: a dict whose ``traceEvents`` is a list of event dicts, each
    carrying ``name``/``ph``/``ts``/``pid``/``tid`` with the right types,
    complete ("X") events a non-negative ``dur``, and JSON-serializable
    ``args``.  The contract CI enforces on every exported trace."""
    if not isinstance(doc, dict):
        raise ValueError(f"chrome trace must be a JSON object, got "
                         f"{type(doc).__name__}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("chrome trace must carry a 'traceEvents' list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for field, types in (("name", str), ("ph", str)):
            if not isinstance(ev.get(field), types):
                raise ValueError(f"traceEvents[{i}] missing/invalid "
                                 f"{field!r}: {ev.get(field)!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"traceEvents[{i}] missing/invalid 'ts'")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                raise ValueError(f"traceEvents[{i}] missing/invalid "
                                 f"{field!r}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"traceEvents[{i}] ('X') needs a "
                                 f"non-negative 'dur', got {dur!r}")
        if "args" in ev:
            try:
                json.dumps(ev["args"])
            except (TypeError, ValueError) as exc:
                raise ValueError(
                    f"traceEvents[{i}] args not JSON-serializable: {exc}")
    # whole-document serializability (catches exotic values outside args)
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as exc:
        raise ValueError(f"chrome trace not JSON-serializable: {exc}")
