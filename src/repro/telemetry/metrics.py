"""Process-global metrics registry: counters, gauges, histograms.

Dependency-free (stdlib only) so every layer of the stack — Pallas kernel
wrappers, the tuner, the engine, the serving scheduler — can import it
without cycles.  The registry is a plain dict of name -> metric; callers
get-or-create through :func:`counter` / :func:`gauge` / :func:`histogram`
and the whole table exports as one JSON-able dict via :func:`snapshot`.

Instrumentation sites guard on ``repro.telemetry.is_enabled()`` (a single
flag check) so the disabled path records nothing and costs nothing; the
metric objects themselves are always safe to touch.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Union

# Histogram sample cap: quantiles are computed over the most recent window
# (serving runs are long; an unbounded list would grow with uptime).
MAX_SAMPLES = 65536


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written level (queue depth, active slots, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Sample distribution with count/sum/min/max and p50/p95/p99 quantiles.

    Samples beyond :data:`MAX_SAMPLES` roll the window (count/sum stay
    lifetime-accurate; quantiles describe the recent window).
    """

    __slots__ = ("name", "count", "sum", "min", "max", "_samples")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if len(self._samples) >= MAX_SAMPLES:
            del self._samples[: MAX_SAMPLES // 2]
        self._samples.append(v)

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile over the sample window (0 when empty)."""
        if not self._samples:
            return 0.0
        xs = sorted(self._samples)
        idx = min(len(xs) - 1, max(0, math.ceil(q * len(xs)) - 1))
        return xs[idx]

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p95(self) -> float:
        return self.quantile(0.95)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> dict:
        return {"type": "histogram", "count": self.count, "sum": self.sum,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "mean": self.mean,
                "p50": self.p50, "p95": self.p95, "p99": self.p99}


MetricT = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name -> metric table with typed get-or-create accessors."""

    def __init__(self):
        self._metrics: Dict[str, MetricT] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls) -> MetricT:
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.setdefault(name, cls(name))
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def get(self, name: str) -> Optional[MetricT]:
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, dict]:
        """JSON-able {name: metric dict}, sorted by name."""
        return {k: m.to_dict() for k, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        self._metrics.clear()

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics


# The process-global registry every subsystem records into.
REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> Dict[str, dict]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
