"""Per-forward execution reports: what actually ran, and why.

An :class:`ExecutionReport` is the engine's per-forward answer to "which
kernel did each conv layer execute, where did its plan come from, and did
anything silently fall back?" — the per-layer attribution the Escoin paper
argues from, produced by ``CnnEngine`` at dispatch time (the dispatch
decisions are static Python over shapes and plan entries, so building the
report never touches a compiled program).

Per :class:`OpReport` fields:

  method_planned / method_executed
      the method the plan (or the caller) asked for vs the one the resolved
      schedule actually runs — they differ exactly when a fallback fired
  fallback_reason
      a machine-readable code from ``repro.telemetry.fallback.REASONS``
      (None on the healthy path)
  provenance
      where the plan entry came from: ``cache_hit`` (persistent plan
      cache, current schema), ``migrated`` (loaded via a v1-v4 schema
      migration or inherited from a legacy un-tagged key),
      ``freshly_tuned`` (scored this run), ``default`` (dense-kept layer
      or no plan entry), ``direct`` (caller forced the method, no plan
      consulted)
  flops / hbm_bytes / staging_stall_s / est_s
      roofline-attributed cost of the *executed* schedule (the
      ``repro.tuning.measure`` cost model over ``launch/roofline.py``
      constants)
  wall_s
      measured wall seconds, filled only by the engine's opt-in timed mode
      (``CnnEngine.forward_timed`` — per-op ``block_until_ready``
      boundaries)

The report-level ``rung`` field names the degradation-ladder rung the
serving tier executed this forward at (``tuned`` / ``quantised`` /
``dense`` — see ``repro.serving.robust``); ``None`` for forwards outside
the ladder (direct engine calls).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.telemetry.trace import TID_ROOFLINE, Tracer


@dataclasses.dataclass
class OpReport:
    """Execution record for one conv op of one forward."""

    name: str
    method_planned: str
    method_executed: str
    provenance: str = "default"
    plan_source: str = "-"               # PlanEntry.source, "-" without one
    fallback_reason: Optional[str] = None
    fuse: bool = False
    tiling: Dict[str, Any] = dataclasses.field(default_factory=dict)
    sparsity: float = 0.0
    value_dtype: str = "float32"         # executed bank value-storage dtype
    flops: float = 0.0
    hbm_bytes: float = 0.0
    staging_stall_s: float = 0.0
    est_s: float = 0.0
    wall_s: Optional[float] = None       # timed mode only

    @property
    def fell_back(self) -> bool:
        return self.fallback_reason is not None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ExecutionReport:
    """One ``CnnEngine`` forward, attributed per conv op."""

    method: str                          # the method the caller requested
    batch: int
    in_shape: Tuple[int, ...]
    dtype: str
    ops: List[OpReport] = dataclasses.field(default_factory=list)
    jit_cache_hit: Optional[bool] = None
    plan_bound: bool = False             # engine had a bound (vs auto) plan
    timed: bool = False
    rung: Optional[str] = None           # degradation-ladder rung executed

    @property
    def fallback_ops(self) -> List[OpReport]:
        return [o for o in self.ops if o.fell_back]

    @property
    def fallback_count(self) -> int:
        return len(self.fallback_ops)

    @property
    def methods_executed(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for o in self.ops:
            out[o.method_executed] = out.get(o.method_executed, 0) + 1
        return out

    @property
    def est_s(self) -> float:
        return sum(o.est_s for o in self.ops)

    def to_dict(self) -> dict:
        return {
            "method": self.method, "batch": self.batch,
            "in_shape": list(self.in_shape), "dtype": self.dtype,
            "jit_cache_hit": self.jit_cache_hit,
            "plan_bound": self.plan_bound, "timed": self.timed,
            "rung": self.rung,
            "fallback_count": self.fallback_count,
            "methods_executed": self.methods_executed,
            "ops": [o.to_dict() for o in self.ops],
        }

    def format(self) -> str:
        """Human-readable per-op table (the paper's per-layer breakdown)."""
        lines = [
            f"ExecutionReport method={self.method} batch={self.batch} "
            f"jit={'hit' if self.jit_cache_hit else 'miss'} "
            f"fallbacks={self.fallback_count}"
            + (f" rung={self.rung}" if self.rung is not None else ""),
            f"{'layer':<22} {'planned':<11} {'executed':<11} "
            f"{'provenance':<13} {'fallback':<20} {'est_us':>9} "
            f"{'stall_us':>9} {'wall_us':>9}",
        ]
        for o in self.ops:
            wall = f"{o.wall_s * 1e6:9.1f}" if o.wall_s is not None else (
                " " * 8 + "-")
            lines.append(
                f"{o.name:<22} {o.method_planned:<11} {o.method_executed:<11} "
                f"{o.provenance:<13} {o.fallback_reason or '-':<20} "
                f"{o.est_s * 1e6:9.1f} {o.staging_stall_s * 1e6:9.1f} {wall}")
        return "\n".join(lines)

    def emit_spans(self, tracer: Tracer) -> None:
        """Lay the per-op roofline estimates out as sequential spans on the
        tracer's ``roofline`` lane.

        The default (untimed) engine executes the whole program as one
        compiled call, so per-op wall segmentation is impossible without
        the timed mode; the estimated timeline still names every op, its
        method, provenance, and any fallback — what the Chrome-trace view
        is for.  Timed-mode wall spans are emitted separately by
        ``CnnEngine.forward_timed`` on the ``wall`` lane.
        """
        import time
        t = time.perf_counter()
        for o in self.ops:
            tracer.complete(
                o.name, start_s=t, dur_s=o.est_s, cat="conv.roofline",
                tid=TID_ROOFLINE,
                args={"estimated": True, "method": o.method_executed,
                      "planned": o.method_planned,
                      "provenance": o.provenance,
                      "fallback": o.fallback_reason,
                      "fuse": o.fuse, "sparsity": o.sparsity,
                      "value_dtype": o.value_dtype,
                      "flops": o.flops, "hbm_bytes": o.hbm_bytes,
                      "staging_stall_s": o.staging_stall_s})
            t += max(o.est_s, 1e-9)
