"""End-to-end serving driver (the paper's kind is inference): batched
requests against a small LM served dense vs through Escoin BCSR weights.

  PYTHONPATH=src python examples/serve_sparse_llm.py --arch yi-9b --gen 24
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    for sparsity in (0.0, 0.8):
        print(f"\n=== serving {args.arch} (smoke config), "
              f"sparsity={sparsity} ===")
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
             "--smoke", "--batch", str(args.batch), "--prompt-len", "16",
             "--gen", str(args.gen), "--sparsity", str(sparsity)],
            check=True)


if __name__ == "__main__":
    main()
