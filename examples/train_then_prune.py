"""Train a small LM, magnitude-prune it, serve it through Escoin BCSR —
the full pruning-for-deployment pipeline around the paper's technique.

  PYTHONPATH=src python examples/train_then_prune.py --steps 120
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, make_loader
from repro.launch.serve import sparsify_params
from repro.launch.steps import init_state, make_serve_step, make_train_step
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sparsity", type=float, default=0.7)
    args = ap.parse_args()

    cfg = ModelConfig(name="lm-28m", family="dense", n_layers=6, d_model=384,
                      vocab=8192, n_heads=6, n_kv_heads=6, head_dim=64,
                      d_ff=1024)
    print(f"model: ~{cfg.num_params() / 1e6:.0f}M params")
    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=args.steps),
                   donate_argnums=(0,))
    loader = make_loader(DataConfig(seq_len=args.seq, global_batch=args.batch,
                                    vocab=cfg.vocab))
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step(state, next(loader))
        losses.append(float(metrics["loss"]))
        if i % 20 == 0:
            print(f"  step {i}: loss={losses[-1]:.4f}")
    loader.close()
    print(f"trained {args.steps} steps in {time.time() - t0:.0f}s; "
          f"loss {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f}")

    # prune + serve
    params = sparsify_params(state["params"], cfg, args.sparsity)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
    cache = T.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(16):
        tok2, cache = serve(params, tok, cache, jnp.int32(i))
        tok = tok2[:, None]
    assert np.isfinite(np.asarray(tok)).all()
    print(f"pruned to sparsity {args.sparsity} and served 16 tokens "
          "through Escoin BCSR — OK")


if __name__ == "__main__":
    main()
