"""Escoin quickstart: prune a conv layer, run it four ways, same answer.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (bcsr_from_dense, bcsr_matmul, block_prune, dense_conv,
                        dense_matmul, direct_sparse_conv, ell_from_dense,
                        ell_from_dense_conv, lowered_sparse_conv,
                        magnitude_prune, measured_sparsity)
from repro.kernels.sparse_conv.ops import sparse_conv

rng = np.random.default_rng(0)

# --- a pruned convolution layer (the paper's setting) ----------------------
x = jnp.asarray(rng.standard_normal((4, 16, 28, 28)).astype(np.float32))
w = jnp.asarray(rng.standard_normal((32, 16, 3, 3)).astype(np.float32))
w = magnitude_prune(w, 0.85)                       # weight pruning
print(f"conv weight sparsity: {float(measured_sparsity(w)):.2f}")

ell = ell_from_dense_conv(np.asarray(w))           # CSR + weight stretching
outs = {
    "dense  (CUBLAS analogue)":   dense_conv(x, w, padding=1),
    "lowered(CUSPARSE analogue)": lowered_sparse_conv(
        x, ell_from_dense(np.asarray(w).reshape(32, -1)), 3, 3, padding=1),
    "escoin direct (pure JAX)":   direct_sparse_conv(x, ell, padding=1),
    "escoin direct (Pallas)":     sparse_conv(x, ell, padding=1, interpret=True),
}
ref = np.asarray(outs["dense  (CUBLAS analogue)"])
for name, o in outs.items():
    err = float(np.max(np.abs(np.asarray(o, np.float32) - ref)))
    print(f"  {name:28s} out={tuple(o.shape)}  max|err|={err:.2e}")

# --- the same technique on a linear layer (BCSR -> MXU path) ----------------
xl = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
wl = jnp.asarray(rng.standard_normal((512, 256)).astype(np.float32))
wl = block_prune(wl, 0.75, (64, 64))               # structured pruning
bc = bcsr_from_dense(np.asarray(wl), (64, 64))
y_dense = dense_matmul(xl, wl)
y_bcsr = bcsr_matmul(xl, bc)
tiles = int(np.asarray(bc.nblocks).sum())
print(f"\nlinear: {tiles}/{(512 // 64) * (256 // 64)} MXU tiles survive pruning"
      f" -> {1 - tiles / 32:.0%} of matmul work skipped,"
      f" max|err|={float(jnp.max(jnp.abs(y_bcsr - y_dense))):.2e}")
print("quickstart OK")
