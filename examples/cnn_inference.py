"""The paper's scenario end-to-end: pruned-CNN inference through Escoin vs
the lowering baselines, per-layer and whole-network.

  PYTHONPATH=src python examples/cnn_inference.py --net alexnet --image 99
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="alexnet", choices=list(cnn.NETWORKS))
    ap.add_argument("--image", type=int, default=99)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    net = cnn.NETWORKS[args.net]()
    rng = np.random.default_rng(0)
    params = cnn.init_cnn(net, 3, rng, args.image)
    x = jnp.asarray(rng.standard_normal(
        (args.batch, 3, args.image, args.image)).astype(np.float32))

    print(f"{args.net}: {len(cnn.conv_layer_shapes(net, 3, args.image))} conv "
          f"layers, image {args.image}, batch {args.batch}")
    ref = None
    for method in ("dense", "lowered", "csr-direct"):
        fn = jax.jit(functools.partial(cnn.cnn_forward, net, params,
                                       method=method))
        out = jax.block_until_ready(fn(x))          # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out = jax.block_until_ready(fn(x))
        dt = (time.perf_counter() - t0) / 3
        if ref is None:
            ref = np.asarray(out)
            err = 0.0
        else:
            err = float(np.max(np.abs(np.asarray(out) - ref)))
        print(f"  {method:10s}: {dt * 1e3:8.1f} ms/batch   max|err|={err:.1e}")
    print("top-1 of first image:", int(np.argmax(ref[0])))


if __name__ == "__main__":
    main()
