"""The paper's scenario end-to-end: pruned-CNN inference through Escoin vs
the lowering baselines, per-layer and whole-network, on the compile-once
graph engine.

The nested spec is lowered once into a flat op program (with conv epilogues
fused at lowering time), a ``CnnEngine`` binds the pruned weights, and each
method runs through the engine's cached jit.

  PYTHONPATH=src python examples/cnn_inference.py --net alexnet --image 99
  PYTHONPATH=src python examples/cnn_inference.py --net resnet50 \
      --methods dense,csr-direct,auto
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import CnnEngine, lower
from repro.models import cnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="alexnet", choices=list(cnn.NETWORKS))
    ap.add_argument("--image", type=int, default=99)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--methods", default="dense,lowered,csr-direct",
                    help="comma-separated subset of "
                         "dense,lowered,csr-direct,pallas,auto "
                         "(pallas runs interpret-mode off-TPU)")
    args = ap.parse_args()

    net = cnn.NETWORKS[args.net]()
    rng = np.random.default_rng(0)
    program = lower(net, (3, args.image, args.image))
    params = cnn.init_cnn(net, 3, rng, args.image)
    engine = CnnEngine(program, params)
    x = jnp.asarray(rng.standard_normal(
        (args.batch, 3, args.image, args.image)).astype(np.float32))

    print(f"{args.net}: lowered once -> {program.summary()}; "
          f"image {args.image}, batch {args.batch}")
    ref = None
    for method in args.methods.split(","):
        out = jax.block_until_ready(engine(x, method))   # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out = jax.block_until_ready(engine(x, method))
        dt = (time.perf_counter() - t0) / 3
        if ref is None:
            ref = np.asarray(out)
            err = 0.0
        else:
            err = float(np.max(np.abs(np.asarray(out) - ref)))
        print(f"  {method:10s}: {dt * 1e3:8.1f} ms/batch   max|err|={err:.1e}")
    print("top-1 of first image:", int(np.argmax(ref[0])))


if __name__ == "__main__":
    main()
