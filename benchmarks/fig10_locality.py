"""Paper Fig. 10 analogue: on-chip memory efficiency.

TPUs have no hardware-managed read-only/texture cache to report hit rates
for; the TPU-native equivalent of the paper's locality argument is the
*explicit VMEM residency plan* of the Pallas kernel (DESIGN.md §2).  Per
sparse CONV layer we report:

  vmem_bytes      -- working set the kernel pins in VMEM (input block +
                     value block + f32 accumulator) at the autotuned TM
  fits            -- whether it fits the 12 MiB budget (=> every input element
                     is read from HBM exactly once per image-tile: the analogue
                     of a 100% read-only-cache hit rate)
  weight_reuse    -- times each nonzero weight is reused out of VMEM (= E*F,
                     paper Fig. 7)
  input_dup_saved -- input duplication factor the direct method avoids vs
                     im2col (R*S)
  ai_direct/ai_lowered -- arithmetic intensity (flops/byte of HBM traffic)
                     of the two methods; higher = less memory-bound

Plus one staging row per network: the aggregate staged-input DMA stall of
the blocking halo schedule vs the double-buffered (pipelined) one — the
paper's locality argument extended from *where the bytes live* to *when
they move*: double buffering overlaps the staging bytes with compute, so
the exposed stall collapses even though the byte count is identical.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.bench_sparse_conv import layer_geometry, layer_record
from benchmarks.common import row
from repro.kernels.sparse_conv.ops import _VMEM_BUDGET, choose_tm
from repro.models import cnn


def run() -> List[str]:
    out = []
    for name in ("alexnet", "googlenet", "resnet50"):
        net = cnn.NETWORKS[name]()
        rng = np.random.default_rng(0)
        image = 224
        shapes = cnn.conv_layer_shapes(net, 3, image)
        # weights for nnz stats only; init at the same 224px geometry —
        # a smaller image collapses GoogLeNet's pool chain and refuses to
        # lower (weights themselves are image-size independent).
        params = cnn.init_cnn(net, 3, rng, image)
        tot_fit = tot = 0
        ai_d_sum = ai_l_sum = 0.0
        for layer, (c, h, w) in shapes:
            if layer.sparsity == 0:
                continue
            ell = params[layer.name]["ell"]
            k = ell.k
            hp, wp = h + 2 * layer.pad, w + 2 * layer.pad
            e = (hp - layer.k) // layer.stride + 1
            f = (wp - layer.k) // layer.stride + 1
            m = layer.out_c
            tm = choose_tm(m, c, hp, wp, e, f, k)
            vmem = c * hp * wp * 4 + tm * k * 4 + tm * e * f * 4
            nnz = float(np.asarray(ell.nnz).sum())
            flops = 2.0 * nnz * e * f
            # direct: read input once + weights once, write output once
            bytes_direct = (c * hp * wp + 2 * nnz + m * e * f) * 4.0
            # lowered: materialise + re-read the duplicated matrix
            bytes_lowered = (2 * c * layer.k * layer.k * e * f
                             + 2 * nnz + m * e * f) * 4.0
            tot += 1
            tot_fit += int(vmem <= _VMEM_BUDGET)
            ai_d_sum += flops / bytes_direct
            ai_l_sum += flops / bytes_lowered
        out.append(row(
            f"fig10/{name}/vmem_fit", 0.0,
            f"layers_fitting_vmem={tot_fit}/{tot};"
            f"mean_AI_direct={ai_d_sum / tot:.2f};"
            f"mean_AI_lowered={ai_l_sum / tot:.2f}"))
        out.append(_staging_row(name, shapes))
    return out


def _staging_row(name: str, shapes) -> str:
    """Aggregate staged-input stall, blocking vs pipelined halo DMA.

    Per-layer pricing is delegated to ``bench_sparse_conv.layer_record`` —
    the same tiling preference and stall model behind
    ``BENCH_sparse_conv.json`` — so fig10 and the bench artifact can never
    disagree about a layer.  Layers with no double-buffered tiling keep
    their blocking stall on both sides of the comparison.
    """
    stall_blk = stall_pip = 0.0
    layers = 0
    for layer, (c, h, w) in shapes:
        if layer.sparsity == 0:
            continue
        rec = layer_record(layer_geometry(layer, c, h, w))
        if rec is None:
            continue  # no Pallas tiling at all: layer runs the fallback
        sch = rec["schedules"]
        stall_blk += sch["blocking"]["staged_stall_ms"] * 1e-3
        stall_pip += sch.get("pipelined",
                             sch["blocking"])["staged_stall_ms"] * 1e-3
        layers += 1
    hidden = 1.0 - stall_pip / stall_blk if stall_blk else 0.0
    return row(
        f"fig10/{name}/staging", stall_pip,
        f"layers={layers};blocking_stall_us={stall_blk * 1e6:.1f};"
        f"pipelined_stall_us={stall_pip * 1e6:.1f};"
        f"stall_hidden={hidden:.1%}")
