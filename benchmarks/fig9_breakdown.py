"""Paper Fig. 9: execution-time breakdown of sparse CONV layers into their
component kernels: im2col / GEMM-or-SpMM (lowering path) vs pad_in / sconv
(Escoin path)."""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import (dense_conv, direct_sparse_conv, ell_matmul, im2col)
from repro.models import cnn
from benchmarks.fig8_sparse_conv import SCALES


def bench_model(name: str) -> List[str]:
    image, batch = SCALES[name]
    net = cnn.NETWORKS[name]()
    rng = np.random.default_rng(0)
    params = cnn.init_cnn(net, 3, rng, image)
    shapes = cnn.conv_layer_shapes(net, 3, image)
    t_im2col = t_spmm = t_pad = t_sconv = t_gemm = 0.0
    for layer, (c, h, w) in shapes:
        if layer.sparsity == 0:
            continue
        x = jnp.asarray(rng.standard_normal((batch, c, h, w)).astype(np.float32))
        entry = params[layer.name]
        jim2col = jax.jit(functools.partial(
            im2col, r=layer.k, s=layer.k, stride=layer.stride,
            padding=layer.pad))
        cols = jim2col(x)
        t_im2col += time_fn(jim2col, x, warmup=1, iters=3)
        # csrmm on the lowered matrix
        t_spmm += time_fn(jax.jit(ell_matmul), cols, entry["ell2d"],
                          warmup=1, iters=3)
        # dense GEMM on the lowered matrix (sgemm)
        wmat = entry["w"].reshape(entry["w"].shape[0], -1)
        t_gemm += time_fn(
            jax.jit(lambda cc, ww: jnp.einsum("npk,mk->nmp", cc, ww)),
            cols, wmat, warmup=1, iters=3)
        # escoin: pad_in + sconv
        pad = layer.pad
        jpad = jax.jit(lambda xx: jnp.pad(
            xx, ((0, 0), (0, 0), (pad, pad), (pad, pad))))
        t_pad += time_fn(jpad, x, warmup=1, iters=3)
        t_sconv += time_fn(
            jax.jit(functools.partial(direct_sparse_conv, stride=layer.stride,
                                      padding=layer.pad)),
            x, entry["ell"], warmup=1, iters=3)
    return [
        row(f"fig9/{name}/im2col", t_im2col, "shared by CUBLAS+CUSPARSE paths"),
        row(f"fig9/{name}/sgemm", t_gemm, "CUBLAS core"),
        row(f"fig9/{name}/csrmm", t_spmm, "CUSPARSE core"),
        row(f"fig9/{name}/pad_in", t_pad, "Escoin pad"),
        row(f"fig9/{name}/sconv", t_sconv, "Escoin core"),
    ]


def run() -> List[str]:
    out = []
    for name in SCALES:
        out += bench_model(name)
    return out
