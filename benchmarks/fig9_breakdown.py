"""Paper Fig. 9: execution-time breakdown of sparse CONV layers into their
component kernels: im2col / GEMM-or-SpMM (lowering path) vs pad_in / sconv
(Escoin path), plus the epilogue passes (bias/ReLU/shortcut) the engine's
fused Pallas path folds into the conv itself.

Geometries come from the engine's single lowering pass (``repro.engine``) —
one ``ConvOp`` per conv with its input shape and fused-epilogue signature
statically resolved.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from benchmarks.fig8_sparse_conv import SCALES
from repro.core import (direct_sparse_conv, ell_matmul, im2col)
from repro.engine import lower
from repro.kernels.sparse_conv.ops import apply_epilogue
from repro.models import cnn


def bench_model(name: str) -> List[str]:
    image, batch = SCALES[name]
    net = cnn.NETWORKS[name]()
    rng = np.random.default_rng(0)
    params = cnn.init_cnn(net, 3, rng, image)
    program = lower(net, (3, image, image))
    t_im2col = t_spmm = t_pad = t_sconv = t_gemm = t_epi = 0.0
    for op in program.conv_ops:
        if op.sparsity == 0:
            continue
        x = jnp.asarray(rng.standard_normal((batch, op.c, op.h, op.w))
                        .astype(np.float32))
        entry = params[op.name]
        jim2col = jax.jit(functools.partial(
            im2col, r=op.k, s=op.k, stride=op.stride, padding=op.pad))
        cols = jim2col(x)
        t_im2col += time_fn(jim2col, x, warmup=1, iters=3)
        # csrmm on the lowered matrix
        t_spmm += time_fn(jax.jit(ell_matmul), cols, entry["ell2d"],
                          warmup=1, iters=3)
        # dense GEMM on the lowered matrix (sgemm)
        wmat = entry["w"].reshape(entry["w"].shape[0], -1)
        t_gemm += time_fn(
            jax.jit(lambda cc, ww: jnp.einsum("npk,mk->nmp", cc, ww)),
            cols, wmat, warmup=1, iters=3)
        # escoin: pad_in + sconv
        pad = op.pad
        jpad = jax.jit(lambda xx: jnp.pad(
            xx, ((0, 0), (0, 0), (pad, pad), (pad, pad))))
        t_pad += time_fn(jpad, x, warmup=1, iters=3)
        t_sconv += time_fn(
            jax.jit(functools.partial(direct_sparse_conv, stride=op.stride,
                                      padding=op.pad)),
            x, entry["ell"], warmup=1, iters=3)
        # epilogue: the unfused bias / ReLU (/ shortcut) passes over the conv
        # output — exactly the HBM traffic the fused Pallas epilogue removes.
        # The shortcut stand-in is a *distinct* tensor: aliasing it to the
        # output would hide the extra HBM read being measured.
        y = jnp.asarray(rng.standard_normal((batch, op.m, op.e, op.f))
                        .astype(np.float32))
        res = (jnp.asarray(rng.standard_normal((batch, op.m, op.e, op.f))
                           .astype(np.float32))
               if op.res is not None else None)
        t_epi += time_fn(
            jax.jit(lambda yy, bb=entry["b"], rr=res, relu=op.fuse_relu:
                    apply_epilogue(yy, bb, relu, rr)),
            y, warmup=1, iters=3)
    return [
        row(f"fig9/{name}/im2col", t_im2col, "shared by CUBLAS+CUSPARSE paths"),
        row(f"fig9/{name}/sgemm", t_gemm, "CUBLAS core"),
        row(f"fig9/{name}/csrmm", t_spmm, "CUSPARSE core"),
        row(f"fig9/{name}/pad_in", t_pad, "Escoin pad"),
        row(f"fig9/{name}/sconv", t_sconv, "Escoin core"),
        row(f"fig9/{name}/epilogue", t_epi,
            "bias/ReLU/shortcut passes; fused in-kernel by the engine"),
    ]


def run() -> List[str]:
    out = []
    for name in SCALES:
        out += bench_model(name)
    return out
