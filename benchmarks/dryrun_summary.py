"""Render the §Dry-run summary (compile proof + memory) for EXPERIMENTS.md."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def main() -> None:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) != 3:
            continue  # tagged hillclimb runs are listed in §Perf instead
        d = json.loads(p.read_text())
        rows.append(d)
    print(f"{len(rows)} cells compiled\n")
    print("| arch | shape | mesh | compile (s) | args/dev (GB) | temp/dev (GB) "
          "| coll/dev (GB, raw scan) | probes |")
    print("|---|---|---|---|---|---|---|---|")
    for d in rows:
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {d.get('compile_s', 0):.0f} "
              f"| {d.get('mem_arg_bytes', 0)/2**30:.2f} "
              f"| {d.get('mem_temp_bytes', 0)/2**30:.2f} "
              f"| {sum(json.loads(json.dumps(d.get('coll_breakdown', {}))).values())/2**30:.2f} "
              f"| {'y' if d.get('probe_info') else '-'} |")


if __name__ == "__main__":
    main()
