"""Render the §Dry-run summary (compile proof + memory) for EXPERIMENTS.md,
and (``--smoke``) a CI-sized regression check of the benchmark tables.

The smoke mode exists so benchmark-table regressions — import errors in a
figure module, renamed rows, a method column silently dropped — fail in CI
instead of at paper-figure time: it imports every suite ``benchmarks.run``
dispatches to, then runs the fig11 end-to-end table on a micro network
(interpret-mode Pallas included) and checks the expected row names.

  PYTHONPATH=src python -m benchmarks.dryrun_summary            # table
  PYTHONPATH=src python -m benchmarks.dryrun_summary --smoke    # CI check
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def render() -> None:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) != 3:
            continue  # tagged hillclimb runs are listed in §Perf instead
        d = json.loads(p.read_text())
        rows.append(d)
    print(f"{len(rows)} cells compiled\n")
    print("| arch | shape | mesh | compile (s) | args/dev (GB) | temp/dev (GB) "
          "| coll/dev (GB, raw scan) | probes |")
    print("|---|---|---|---|---|---|---|---|")
    for d in rows:
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {d.get('compile_s', 0):.0f} "
              f"| {d.get('mem_arg_bytes', 0)/2**30:.2f} "
              f"| {d.get('mem_temp_bytes', 0)/2**30:.2f} "
              f"| {sum(json.loads(json.dumps(d.get('coll_breakdown', {}))).values())/2**30:.2f} "
              f"| {'y' if d.get('probe_info') else '-'} |")


def smoke() -> None:
    """Import every benchmark suite and spot-check the fig11 table rows."""
    # Import errors in any figure module fail here, like benchmarks.run would.
    from benchmarks import (fig8_sparse_conv, fig9_breakdown,  # noqa: F401
                            fig10_locality, fig11_end2end, fig12_autotune,
                            kernels, roofline_table, run)
    from repro.models import cnn

    micro = [
        cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
        cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu(),
        cnn.Pool("gap"), cnn.FC("fc", 10),
    ]
    rows = fig11_end2end.bench_network("micro", micro, image=8, batch=1,
                                       iters=1, pallas_iters=1)
    names = {r.split(",")[0] for r in rows}
    expect = {f"fig11/micro/{m}" for m in fig11_end2end.METHOD_ROWS}
    missing = expect - names
    if missing:
        raise SystemExit(f"benchmark smoke: missing fig11 rows {sorted(missing)}")
    for r in rows:
        print(r)
    print(f"benchmark smoke ok: {len(names)} fig11 rows, all suites import")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI regression check of the benchmark tables")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        render()


if __name__ == "__main__":
    main()
