"""Render the §Dry-run summary (compile proof + memory) for EXPERIMENTS.md,
and (``--smoke``) a CI-sized regression check of the benchmark tables.

The smoke mode exists so benchmark-table regressions — import errors in a
figure module, renamed rows, a method column silently dropped — fail in CI
instead of at paper-figure time: it imports every suite ``benchmarks.run``
dispatches to, then runs the fig11 end-to-end table on a micro network
(interpret-mode Pallas included) and checks the expected row names.

  PYTHONPATH=src python -m benchmarks.dryrun_summary            # table
  PYTHONPATH=src python -m benchmarks.dryrun_summary --smoke    # CI check
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def render() -> None:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        parts = p.stem.split("__")
        if len(parts) != 3:
            continue  # tagged hillclimb runs are listed in §Perf instead
        d = json.loads(p.read_text())
        rows.append(d)
    print(f"{len(rows)} cells compiled\n")
    print("| arch | shape | mesh | compile (s) | args/dev (GB) | temp/dev (GB) "
          "| coll/dev (GB, raw scan) | probes |")
    print("|---|---|---|---|---|---|---|---|")
    for d in rows:
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {d.get('compile_s', 0):.0f} "
              f"| {d.get('mem_arg_bytes', 0)/2**30:.2f} "
              f"| {d.get('mem_temp_bytes', 0)/2**30:.2f} "
              f"| {sum(json.loads(json.dumps(d.get('coll_breakdown', {}))).values())/2**30:.2f} "
              f"| {'y' if d.get('probe_info') else '-'} |")


def smoke() -> None:
    """Import every benchmark suite and spot-check the fig11 table rows, the
    BENCH_sparse_conv.json schedule rows (pipeline axis + the bsr MXU
    crossover + the zero-silent-fallback invariant), the plan-cache v1→v5
    migrations, and one telemetry-traced engine forward (valid Chrome-trace
    JSON, per-op ExecutionReport, zero fallbacks)."""
    # Import errors in any figure module fail here, like benchmarks.run would.
    from benchmarks import (bench_sparse_conv, fig8_sparse_conv,  # noqa: F401
                            fig9_breakdown, fig10_locality, fig11_end2end,
                            fig12_autotune, kernels, roofline_table, run)
    from repro.models import cnn

    micro = [
        cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
        cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu(),
        cnn.Pool("gap"), cnn.FC("fc", 10),
    ]
    rows = fig11_end2end.bench_network("micro", micro, image=8, batch=1,
                                       iters=1, pallas_iters=1)
    names = {r.split(",")[0] for r in rows}
    expect = {f"fig11/micro/{m}" for m in fig11_end2end.METHOD_ROWS}
    missing = expect - names
    if missing:
        raise SystemExit(f"benchmark smoke: missing fig11 rows {sorted(missing)}")
    for r in rows:
        print(r)
    _smoke_bench_json(bench_sparse_conv)
    _smoke_cache_migrations()
    _smoke_traced_forward()
    _smoke_quantised_forward()
    _smoke_chaos_forward()
    _smoke_static_verifier()
    print(f"benchmark smoke ok: {len(names)} fig11 rows, all suites import, "
          "bench json pipeline + bsr + quantised rows + zero fallbacks, "
          "cache v1-v5 -> v6 migrations, traced + int8-pinned forwards "
          "valid, chaos serving zero-lost + degradation recorded, "
          "static verifier clean")


def _smoke_bench_json(bench_sparse_conv) -> None:
    """BENCH_sparse_conv.json must carry both halo-DMA schedule rows plus a
    bsr (MXU) row, the pipelined staged-input stalls must be strictly fewer,
    and at least one moderate-sparsity layer must cross over to the bsr
    path under roofline auto-selection."""
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        path = pathlib.Path(td) / "bench.json"
        bench_sparse_conv.run(str(path), networks=["alexnet"], wall=False)
        doc = json.loads(path.read_text())
        layers = doc["networks"]["alexnet"]["layers"]
        if not layers:
            raise SystemExit("bench smoke: no sparse-conv layer records")
        for rec in layers:
            sch = rec["schedules"]
            if "blocking" not in sch or "pipelined" not in sch:
                raise SystemExit(
                    f"bench smoke: {rec['name']} missing a schedule row")
            if "auto_roofline" not in rec:
                raise SystemExit(
                    f"bench smoke: {rec['name']} missing the auto row")
        if not any("bsr" in rec["schedules"] for rec in layers):
            raise SystemExit("bench smoke: no bsr (MXU) schedule rows")
        for rec in layers:
            if "blocking_int8" not in rec["schedules"]:
                raise SystemExit(
                    f"bench smoke: {rec['name']} missing the int8 twin row")
            if "value_dtype" not in rec.get("auto_roofline", {}):
                raise SystemExit(
                    f"bench smoke: {rec['name']} auto row missing "
                    f"value_dtype")
        # the invariants already ran inside run(); assert they are wired
        bench_sparse_conv.check_stall_invariant(doc)
        bench_sparse_conv.check_mxu_crossover(doc)
        bench_sparse_conv.check_zero_fallback(doc)
        bench_sparse_conv.check_quantised_bytes(doc)
        # every record must carry the fallback field (null == plan runs)
        for rec in layers:
            if "fallback" not in rec:
                raise SystemExit(
                    f"bench smoke: {rec['name']} missing the fallback field")


def _smoke_cache_migrations() -> None:
    """Every migratable plan-cache schema (v1-v5) loads, defaults the fields
    its kernels predate, and re-persists as the current version."""
    import tempfile

    from repro.tuning.cache import CACHE_VERSION, MIGRATABLE_VERSIONS, PlanCache

    fixtures = {
        1: {"method": "pallas", "tm": 64, "pad_to": 8},
        2: {"method": "pallas", "tm": 32, "te": 16, "tf": 16, "pad_to": 8},
        3: {"method": "pallas", "tm": 16, "te": 16, "tf": 16, "pad_to": 8,
            "fuse": True},
        4: {"method": "pallas", "tm": 16, "te": 16, "tf": 16, "pad_to": 8,
            "fuse": True, "pipeline": True, "permute": True},
        5: {"method": "bsr", "te": 16, "tf": 16, "fuse": True,
            "block_m": 8, "block_n": 128},
    }
    if set(fixtures) != set(MIGRATABLE_VERSIONS):
        raise SystemExit("cache smoke: fixture set out of date with "
                         f"MIGRATABLE_VERSIONS={MIGRATABLE_VERSIONS}")
    with tempfile.TemporaryDirectory() as td:
        for ver, entry in fixtures.items():
            p = pathlib.Path(td) / f"v{ver}.json"
            p.write_text(json.dumps({"version": ver, "entries": {"k": entry}}))
            cache = PlanCache(str(p))
            pe = cache.get("k")
            if ver < 4 and (pe.pipeline or pe.permute):
                raise SystemExit(
                    f"cache smoke: v{ver} entry migrated with a non-blocking "
                    "schedule")
            if ver < 5 and (pe.block_m is not None or pe.block_n is not None):
                raise SystemExit(
                    f"cache smoke: v{ver} entry migrated with a BCSR block "
                    "shape no pre-v5 kernel ran")
            if pe.value_dtype != "float32":
                raise SystemExit(
                    f"cache smoke: v{ver} entry migrated with a quantised "
                    "value stream no pre-v6 kernel ran")
            out = pathlib.Path(td) / f"v{ver}-migrated.json"
            cache.save(str(out))
            doc = json.loads(out.read_text())
            if doc["version"] != CACHE_VERSION:
                raise SystemExit(
                    f"cache smoke: v{ver} re-persisted as {doc['version']}, "
                    f"want {CACHE_VERSION}")


def _smoke_traced_forward() -> None:
    """One telemetry-enabled engine forward on a micro network must produce
    a per-op ExecutionReport with zero silent fallbacks and a Chrome-trace
    JSON that passes schema validation."""
    import tempfile

    import numpy as np

    from repro import telemetry
    from repro.engine import CnnEngine, lower
    from repro.models import cnn
    from repro.tuning import PlanCache, apply_plan_to_params, plan_program

    micro = [
        cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
        cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu(),
        cnn.Pool("gap"), cnn.FC("fc", 10),
    ]
    rng = np.random.default_rng(0)
    program = lower(micro, (3, 8, 8))
    params = cnn.init_cnn(micro, 3, rng, 8)
    plan = plan_program(program, batch=1, mode="roofline", cache=PlanCache())
    apply_plan_to_params(params, plan)
    engine = CnnEngine(program, params, plan)
    x = rng.standard_normal((1, 3, 8, 8)).astype(np.float32)

    telemetry.reset()
    with telemetry.enabled():
        engine(x, "auto")
        report = engine.last_report
        if report is None:
            raise SystemExit("trace smoke: no ExecutionReport recorded")
        if report.fallback_count:
            raise SystemExit(
                "trace smoke: traced forward took silent fallbacks: "
                f"{[(o.name, o.fallback_reason) for o in report.fallback_ops]}")
        conv_ops = [o for o in report.ops]
        if not conv_ops or any(not o.method_executed for o in conv_ops):
            raise SystemExit("trace smoke: report missing per-op methods")
        tracer = telemetry.get_tracer()
        if len(tracer) < len(conv_ops):
            raise SystemExit(
                f"trace smoke: {len(tracer)} trace events for "
                f"{len(conv_ops)} conv ops")
        with tempfile.TemporaryDirectory() as td:
            path = pathlib.Path(td) / "trace.json"
            tracer.export(str(path))  # export() validates before writing
            doc = json.loads(path.read_text())
            telemetry.validate_chrome_trace(doc)
            if not any(ev.get("ph") == "X" for ev in doc["traceEvents"]):
                raise SystemExit("trace smoke: no complete (X) span events")
    telemetry.reset()


def _smoke_quantised_forward() -> None:
    """One engine forward with an int8-pinned plan (the CI bench-smoke leg
    for the quantised value streams): every sparse conv must execute its
    planned kernel on the int8 bank — no silent fallbacks — and the output
    must agree with the f32-bank forward to quantisation tolerance."""
    import dataclasses

    import numpy as np

    from repro import telemetry
    from repro.engine import CnnEngine, lower
    from repro.models import cnn
    from repro.tuning import PlanCache, apply_plan_to_params, plan_program

    micro = [
        cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
        cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu(),
        cnn.Pool("gap"), cnn.FC("fc", 10),
    ]
    rng = np.random.default_rng(0)
    program = lower(micro, (3, 8, 8))
    params = cnn.init_cnn(micro, 3, rng, 8)
    plan = plan_program(program, batch=1, mode="roofline", cache=PlanCache())
    plan = {name: (dataclasses.replace(pe, value_dtype="int8")
                   if pe.method in ("pallas", "bsr") else pe)
            for name, pe in plan.items()}
    if not any(pe.value_dtype == "int8" for pe in plan.values()):
        raise SystemExit("quantised smoke: no pallas/bsr entry to pin int8")
    qparams = apply_plan_to_params(params, plan)
    x = rng.standard_normal((1, 3, 8, 8)).astype(np.float32)
    engine = CnnEngine(program, qparams, plan, strict=True)
    telemetry.reset()
    with telemetry.enabled():
        y_q = np.asarray(engine(x, "auto"))
        report = engine.last_report
    telemetry.reset()
    if report is None or report.fallback_count:
        raise SystemExit(
            "quantised smoke: int8-pinned forward took silent fallbacks: "
            f"{[(o.name, o.fallback_reason) for o in report.fallback_ops]}")
    if not any(o.value_dtype == "int8" for o in report.ops):
        raise SystemExit(
            "quantised smoke: no op executed an int8 value stream")
    y_f = np.asarray(CnnEngine(program, params, None)(x, "dense"))
    denom = float(np.abs(y_f).max()) or 1.0
    rel = float(np.abs(y_q - y_f).max()) / denom
    if not np.isfinite(rel) or rel > 0.05:
        raise SystemExit(
            f"quantised smoke: int8 forward diverges from f32 (rel={rel})")


def _smoke_chaos_forward() -> None:
    """The fault-tolerant CNN serving tier must complete a seeded chaos
    trace with zero lost/duplicated requests and recorded degradation
    evidence, and a corrupted plan-cache file must degrade resiliently
    (``PlanCacheWarning``), never crash the server build."""
    import tempfile
    import warnings

    import numpy as np

    from repro.engine import init_conv_params, lower
    from repro.serving import (BucketSpec, ChaosConfig, ChaosInjector,
                               RobustCnnServer, VirtualClock, arrival_trace,
                               corrupt_plan_cache_file, slice_net)
    from repro.tuning import PlanCache, plan_program
    from repro.tuning.cache import PlanCacheWarning

    net = slice_net("alexnet")
    program = lower(net, (3, 12, 12))
    params = init_conv_params(program, np.random.default_rng(0))
    with tempfile.TemporaryDirectory() as td:
        # Plan-cache corruption seam: persist a tuned cache, mangle it on
        # disk, and build the server against the corrupted file.
        cache_path = str(pathlib.Path(td) / "plans.json")
        cache = PlanCache(cache_path)
        plan_program(program, batch=2, mode="roofline", cache=cache,
                     params=params)
        corrupt_plan_cache_file(cache_path, mode="garbage")
        chaos = ChaosInjector(ChaosConfig(
            seed=0, step_fault_rate=0.4, plan_corruption_rate=1.0,
            straggler_rate=0.1))
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            server = RobustCnnServer(
                net, params, [BucketSpec(3, 12, 12, batch=2)],
                plan_cache=cache_path, clock=VirtualClock(), queue_depth=16,
                max_attempts=6, chaos=chaos)
        if not any(issubclass(w.category, PlanCacheWarning) for w in caught):
            raise SystemExit(
                "chaos smoke: corrupted plan cache loaded without a "
                "PlanCacheWarning")
    trace = arrival_trace(20, [(3, 12, 12)], seed=1, mean_gap_s=0.0005,
                          deadline_s=(1.0, 2.0))
    rep = server.run_trace(trace)
    if rep.lost or rep.duplicated:
        raise SystemExit(
            f"chaos smoke: {rep.lost} lost / {rep.duplicated} duplicated "
            f"request(s) under injected faults")
    if not (rep.degradations or rep.dropped_rungs):
        raise SystemExit(
            "chaos smoke: chaos run recorded no degradation event")
    if not chaos.corrupted_entries:
        raise SystemExit("chaos smoke: plan corruption seam never fired")


def _smoke_static_verifier() -> None:
    """The pre-flight verifier must report zero errors over every network,
    its shipped default plan, and the kernel sources — the same gate CI's
    static-analysis job runs via `python -m repro.analysis check`."""
    from repro.analysis.checker import run_check

    report = run_check()
    if report.errors:
        raise SystemExit(
            "static-verifier smoke: "
            + "; ".join(d.format() for d in report.errors))
    if report.warnings:
        raise SystemExit(
            "static-verifier smoke: unexpected warnings: "
            + "; ".join(d.format() for d in report.warnings))
    if not report.checked:
        raise SystemExit("static-verifier smoke: nothing was checked")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI regression check of the benchmark tables")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        render()


if __name__ == "__main__":
    main()
