"""Benchmark timing helpers (CPU wall-time; TPU numbers come from §Roofline)."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (seconds) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
