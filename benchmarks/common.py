"""Benchmark timing helpers (CPU wall-time; TPU numbers come from §Roofline).

The timer itself lives in ``repro.tuning.measure`` — the autotuner and the
benchmark suites share one warmup/median-of-k implementation.
"""
from __future__ import annotations

from repro.tuning.measure import time_fn  # noqa: F401  (re-export)


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
