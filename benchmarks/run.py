"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig8 fig11 # subset
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig8_sparse_conv, fig9_breakdown, fig10_locality,
                            fig11_end2end, kernels, roofline_table)
    suites = {
        "fig8": fig8_sparse_conv.run,
        "fig9": fig9_breakdown.run,
        "fig10": fig10_locality.run,
        "fig11": fig11_end2end.run,
        "kernels": kernels.run,
        "roofline": roofline_table.run,
    }
    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for key in wanted:
        for line in suites[key]():
            print(line, flush=True)


if __name__ == "__main__":
    main()
