"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The ``bench`` suite
additionally writes ``BENCH_sparse_conv.json`` — the machine-readable
per-layer perf record (kernel roofline ms under blocking vs pipelined halo
staging, staged-input stalls, wall-clock for the record) that tracks the
sparse-conv trajectory PR-over-PR.

  PYTHONPATH=src python -m benchmarks.run                  # everything
  PYTHONPATH=src python -m benchmarks.run fig8 fig11       # subset
  PYTHONPATH=src python -m benchmarks.run fig8 --autotune  # + tuned row
  PYTHONPATH=src python -m benchmarks.run bench            # + the JSON
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_sparse_conv, fig8_sparse_conv,
                            fig9_breakdown, fig10_locality, fig11_end2end,
                            fig12_autotune, kernels, roofline_table)
    argv = sys.argv[1:]
    autotune = "--autotune" in argv
    suites = {
        "fig8": lambda: fig8_sparse_conv.run(autotune=autotune),
        "fig9": fig9_breakdown.run,
        "fig10": fig10_locality.run,
        "fig11": fig11_end2end.run,
        "fig12": fig12_autotune.run,
        "kernels": kernels.run,
        "roofline": roofline_table.run,
        "bench": bench_sparse_conv.run,
    }
    wanted = [a for a in argv if not a.startswith("--")] or list(suites)
    print("name,us_per_call,derived")
    for key in wanted:
        for line in suites[key]():
            print(line, flush=True)


if __name__ == "__main__":
    main()
