"""Paper Fig. 8: execution time of sparse CONV layers, per model x method,
normalized to the dense (CUBLAS-analogue) approach.

Methods: dense (CUBLAS), lowered (CUSPARSE: im2col + CSR SpMM), csr-direct
(Escoin, pure-JAX direct sparse conv).  The Pallas kernels (the ELL VPU
path and the BCSR ``bsr`` MXU path) run in interpret mode on CPU
(Python-executed), so their wall times are *not* comparable — their
performance cases are made by the roofline model; the bsr row reports its
projected MXU-vs-dense speedup from that model.

CPU wall-times do not reproduce GPU magnitudes; the comparison of *methods*
on identical shapes/sparsities is the reproduction target.
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import dense_conv, direct_sparse_conv, lowered_sparse_conv
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import ell_from_dense, ell_from_dense_conv
from repro.models import cnn

# reduced-scale geometry for CPU timing (methods see identical shapes)
SCALES = {"alexnet": (99, 4), "googlenet": (96, 2), "resnet50": (96, 2)}

# Strided sparse layers (reduced-scale stand-ins for AlexNet conv1-class
# stride-4 stems and ResNet stride-2 bottleneck entries).  These are the
# layers the old Pallas kernel refused (stride != 1 fell back to pure JAX);
# the spatially-tiled kernel runs them in-kernel, so they get their own
# fig8 rows: (name, C, H, M, R, stride, pad, sparsity).
STRIDED_LAYERS = [
    ("stem_s4", 3, 99, 96, 11, 4, 0, 0.80),
    ("res_s2", 64, 48, 64, 3, 2, 1, 0.70),
]


def bench_model(name: str, *, iters: int = 3, autotune: bool = False) -> List[str]:
    image, batch = SCALES[name]
    net = cnn.NETWORKS[name]()
    rng = np.random.default_rng(0)
    params = cnn.init_cnn(net, 3, rng, image)
    shapes = cnn.conv_layer_shapes(net, 3, image)
    totals: Dict[str, float] = {"dense": 0.0, "lowered": 0.0, "csr-direct": 0.0}
    for layer, (c, h, w) in shapes:
        if layer.sparsity == 0:
            continue  # paper: only sparse CONV layers in this figure
        x = jnp.asarray(rng.standard_normal((batch, c, h, w)).astype(np.float32))
        entry = params[layer.name]
        fns = {
            "dense": jax.jit(functools.partial(
                dense_conv, stride=layer.stride, padding=layer.pad)),
            "lowered": jax.jit(functools.partial(
                lowered_sparse_conv, r=layer.k, s=layer.k,
                stride=layer.stride, padding=layer.pad)),
            "csr-direct": jax.jit(functools.partial(
                direct_sparse_conv, stride=layer.stride, padding=layer.pad)),
        }
        args = {"dense": (x, entry["w"]), "lowered": (x, entry["ell2d"]),
                "csr-direct": (x, entry["ell"])}
        for m in totals:
            totals[m] += time_fn(fns[m], *args[m], warmup=1, iters=iters)
    # Analytic TPU projection per method, summed over the sparse layers at
    # the paper's full 224px geometry and batch 128.  All rows come from
    # ONE model — the tuner's roofline (`tuning.measure.roofline_estimate`,
    # MXU peak for dense/bsr contractions, VPU FMA rate for the per-nonzero
    # loops) — so the figure's projected speedups are mutually comparable;
    # the old hand-rolled flat-peak formulas priced every method at the MXU
    # peak and overstated the scan paths ~8x relative to the bsr row.  The
    # bsr row is roofline-only (interpret-mode wall time is not comparable,
    # same policy as the ELL Pallas kernel) and assumes block-structured
    # pruning at each layer's sparsity — the flexibility the BCSR path
    # trades for MXU throughput.
    from repro.tuning import Candidate, roofline_estimate
    from benchmarks.bench_sparse_conv import best_bsr_candidate, layer_geometry
    proj = {"dense": 0.0, "lowered": 0.0, "csr-direct": 0.0}
    t_bsr_rf = 0.0
    full_shapes = cnn.conv_layer_shapes(net, 3, 224)
    for layer, (c, h, w) in full_shapes:
        if layer.sparsity == 0:
            continue
        g = layer_geometry(layer, c, h, w, batch=128)  # paper batch
        for m in proj:
            proj[m] += roofline_estimate(
                g, Candidate(m, pad_to=None if m == "dense" else 8))
        cand = best_bsr_candidate(g)
        if cand is not None:
            t_bsr_rf += roofline_estimate(g, cand)
    out = []
    base = totals["dense"]
    for m, t in totals.items():
        out.append(row(
            f"fig8/{name}/{m}", t,
            f"speedup_vs_dense={base / t:.2f};"
            f"tpu_projected_speedup={proj['dense'] / proj[m]:.2f}"))
    if t_bsr_rf:
        out.append(row(
            f"fig8/{name}/bsr", t_bsr_rf,
            f"roofline_only=1;"
            f"tpu_projected_speedup={proj['dense'] / t_bsr_rf:.2f}"))
    if autotune:
        # Measurement-driven per-layer method selection (repro.tuning): the
        # tuned total is the sum of each sparse layer's winning wall time
        # (epilogue included — the tuner times conv+bias/ReLU/shortcut as
        # one unit since the fused kernel executes them as one).  The dense
        # baseline for this row is therefore re-measured epilogue-inclusive:
        # dividing the conv-only `base` by an epilogue-inclusive tuned total
        # would understate the tuned speedup.
        from repro.engine import lower
        from repro.tuning import (Candidate, PlanCache, geometry_of_op,
                                  measure_candidate, plan_program)
        program = lower(net, (3, image, image))
        plan = plan_program(program, batch=batch, mode="wall",
                            cache=PlanCache(), params=params, iters=iters)
        t_auto = t_dense_epi = 0.0
        for op in program.conv_ops:
            if op.sparsity == 0:
                continue
            t_auto += plan[op.name].est_s
            g = geometry_of_op(op, batch=batch)
            x = jnp.asarray(rng.standard_normal(
                (batch, op.c, op.h, op.w)).astype(np.float32))
            t_dense_epi += measure_candidate(
                g, Candidate("dense"), np.asarray(params[op.name]["w"]), x,
                iters=iters)
        out.append(row(f"fig8/{name}/auto", t_auto,
                       f"speedup_vs_dense={t_dense_epi / t_auto:.2f}"))
    return out


def bench_strided(*, iters: int = 3, batch: int = 2) -> List[str]:
    """Per-method wall rows for strided sparse layers (stride 2 and 4).

    The Pallas kernel itself is interpret-mode on CPU (not wall-comparable,
    same policy as the per-model rows); its strided coverage is exercised by
    the tier-1 parity tests and ranked by the tuner's roofline model.
    """
    rng = np.random.default_rng(0)
    out: List[str] = []
    for name, c, h, m, r, stride, pad, sp in STRIDED_LAYERS:
        x = jnp.asarray(rng.standard_normal((batch, c, h, h)).astype(np.float32))
        wt = np.asarray(magnitude_prune(jnp.asarray(
            rng.standard_normal((m, c, r, r)).astype(np.float32)), sp))
        ell = ell_from_dense_conv(wt)
        ell2d = ell_from_dense(wt.reshape(m, -1))
        fns = {
            "dense": jax.jit(functools.partial(
                dense_conv, stride=stride, padding=pad)),
            "lowered": jax.jit(functools.partial(
                lowered_sparse_conv, r=r, s=r, stride=stride, padding=pad)),
            "csr-direct": jax.jit(functools.partial(
                direct_sparse_conv, stride=stride, padding=pad)),
        }
        args = {"dense": (x, jnp.asarray(wt)), "lowered": (x, ell2d),
                "csr-direct": (x, ell)}
        base = None
        for meth in ("dense", "lowered", "csr-direct"):
            t = time_fn(fns[meth], *args[meth], warmup=1, iters=iters)
            base = t if base is None else base
            out.append(row(
                f"fig8/strided/{name}/{meth}", t,
                f"stride={stride};speedup_vs_dense={base / t:.2f}"))
    return out


def run(autotune: bool = False) -> List[str]:
    lines = []
    for name in SCALES:
        lines += bench_model(name, autotune=autotune)
    lines += bench_strided()
    return lines
