"""Render the §Roofline table for EXPERIMENTS.md from experiments/dryrun/*.json."""
from __future__ import annotations

import json
import pathlib
from typing import List, Optional

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(tag: Optional[str] = None) -> List[dict]:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        parts = p.stem.split("__")
        rtag = parts[3] if len(parts) > 3 else ""
        if (tag or "") != rtag:
            continue
        rows.append(json.loads(p.read_text()))
    return rows


def markdown_table(rows: List[dict], mesh: str = "16x16") -> str:
    """Single-pod roofline table.  Cells without probe extrapolation carry a
    '*' and omit useful/roofline (raw scanned counts count loop bodies once,
    so those ratios would be meaningless)."""
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bound | useful | roofline | peak mem/dev (GB) |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["mesh"] != mesh:
            continue
        probed = bool(r.get("probe_info"))
        star = "" if probed else "*"
        useful = f"{r['useful_ratio']:.2f}" if probed else "-"
        frac = f"{r['roofline_fraction']:.3f}" if probed else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']}{star} "
            f"| {r['t_compute']*1e3:.1f} | {r['t_memory']*1e3:.1f} "
            f"| {r['t_collective']*1e3:.1f} | {r['bottleneck']} "
            f"| {useful} | {frac} "
            f"| {(r.get('peak_mem_bytes') or 0)/2**30:.1f} |")
    return hdr + "\n".join(lines)


def run() -> List[str]:
    rows = load()
    out = []
    for r in rows:
        out.append(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
            f"{max(r['t_compute'], r['t_memory'], r['t_collective'])*1e6:.1f},"
            f"bound={r['bottleneck']};roofline_frac={r['roofline_fraction']:.3f}")
    return out


if __name__ == "__main__":
    print(markdown_table(load()))
