"""Paper Fig. 11: end-to-end inference time per model x method (all layers,
not just sparse CONV), normalized to the dense (CUBLAS) approach.

Runs through the compile-once graph engine (``repro.engine``): one lowering
pass per network, one cached-jit executable per method.  Beyond the paper's
dense/lowered/csr-direct columns this table carries the Pallas rows —
``pallas`` (fused in-kernel epilogue), ``pallas-unfused`` (the three-pass
bias/ReLU/shortcut baseline the fusion removes), and ``auto`` (tuned
per-layer dispatch).  On CPU the Pallas kernel executes in interpret mode,
so those wall times are *not* hardware-comparable — the fused-vs-unfused
pair documents the schedule difference (its performance case is the
roofline's saved output passes), and the rows keep the table's names and
imports regression-tested.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from benchmarks.fig8_sparse_conv import SCALES
from repro.engine import CnnEngine, lower
from repro.models import cnn

METHOD_ROWS = ("dense", "lowered", "csr-direct", "pallas", "pallas-unfused",
               "auto")


def bench_network(name: str, net: Sequence[Any], image: int, batch: int, *,
                  iters: int = 3, pallas_iters: int = 1) -> List[str]:
    """End-to-end rows for one network through a bound engine."""
    rng = np.random.default_rng(0)
    program = lower(net, (3, image, image))
    params = cnn.init_cnn(net, 3, rng, image)
    engine = CnnEngine(program, params)
    x = jnp.asarray(rng.standard_normal((batch, 3, image, image))
                    .astype(np.float32))
    times: Dict[str, float] = {}
    for method in ("dense", "lowered", "csr-direct", "auto"):
        times[method] = time_fn(lambda xx, m=method: engine(xx, m), x,
                                warmup=1, iters=iters)
    # Interpret-mode Pallas (Python-executed on CPU): fewer iters, and the
    # fused-vs-unfused pair shows the epilogue collapse end-to-end.
    times["pallas"] = time_fn(lambda xx: engine(xx, "pallas"), x,
                              warmup=1, iters=pallas_iters)
    times["pallas-unfused"] = time_fn(
        lambda xx: engine(xx, "pallas", fuse=False), x,
        warmup=1, iters=pallas_iters)
    base = times["dense"]
    out = []
    for m in METHOD_ROWS:
        t = times[m]
        derived = f"speedup_vs_dense={base / t:.2f}"
        if m.startswith("pallas"):
            derived += ";interpret=1"
        if m == "pallas-unfused":
            derived += f";fused_speedup={t / times['pallas']:.2f}"
        out.append(row(f"fig11/{name}/{m}", t, derived))
    return out


def run() -> List[str]:
    out = []
    for name in SCALES:
        image, batch = SCALES[name]
        out += bench_network(name, cnn.NETWORKS[name](), image, batch)
    return out
