"""Paper Fig. 11: end-to-end inference time per model x method (all layers,
not just sparse CONV), normalized to the dense (CUBLAS) approach."""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from benchmarks.fig8_sparse_conv import SCALES
from repro.models import cnn


def run() -> List[str]:
    out = []
    for name in SCALES:
        image, batch = SCALES[name]
        net = cnn.NETWORKS[name]()
        rng = np.random.default_rng(0)
        params = cnn.init_cnn(net, 3, rng, image)
        x = jnp.asarray(rng.standard_normal((batch, 3, image, image))
                        .astype(np.float32))
        times = {}
        for method in ("dense", "lowered", "csr-direct"):
            fn = jax.jit(functools.partial(cnn.cnn_forward, net, params,
                                           method=method))
            times[method] = time_fn(fn, x, warmup=1, iters=3)
        base = times["dense"]
        for m, t in times.items():
            out.append(row(f"fig11/{name}/{m}", t,
                           f"speedup_vs_dense={base / t:.2f}"))
    return out
