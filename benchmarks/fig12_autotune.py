"""Beyond-paper Fig. 12: tuned-vs-fixed speedup per layer.

For every model, the autotuner (``repro.tuning``) measures each candidate
(method x (tm, te, tf) x pad_to x fuse) per *distinct* sparse conv geometry
and picks a winner; this table reports, per geometry, the tuned wall time
against each fixed single-method baseline — the measured counterpart of the
paper's kernel-customization table (§3.3-3.4).

Geometries come from the engine's lowered program, so the dedup key carries
each conv's fused-epilogue signature: a bottleneck tail (fused shortcut)
and a plain conv+ReLU with the same shape are distinct rows, exactly like
the planner's cache.  Duplicate geometries (repeated ResNet bottlenecks,
inception twins) share a key and are reported once; totals weight each
geometry by its occurrence count.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from benchmarks.fig8_sparse_conv import SCALES
from repro.engine import lower
from repro.models import cnn
from repro.tuning import (Candidate, PlanCache, geometry_of_op, layer_key,
                          measure_candidate, plan_program)

FIXED = ("dense", "lowered", "csr-direct")


def bench_model(name: str, *, iters: int = 3) -> List[str]:
    image, batch = SCALES[name]
    net = cnn.NETWORKS[name]()
    rng = np.random.default_rng(0)
    params = cnn.init_cnn(net, 3, rng, image)
    program = lower(net, (3, image, image))
    cache = PlanCache()
    plan = plan_program(program, batch=batch, mode="wall",
                        cache=cache, params=params, iters=iters)
    lines: List[str] = []
    seen: Dict[str, Dict[str, float]] = {}
    totals = {m: 0.0 for m in FIXED}
    t_tuned = 0.0
    for op in program.conv_ops:
        if op.sparsity == 0:
            continue
        g = geometry_of_op(op, batch=batch)
        key = layer_key(g, "cpu")
        if key in seen:
            fixed = seen[key]
        else:
            x = jnp.asarray(rng.standard_normal(
                (batch, op.c, op.h, op.w)).astype(np.float32))
            wd = np.asarray(params[op.name]["w"])
            fixed = {
                m: measure_candidate(
                    g, Candidate(m, pad_to=None if m == "dense" else 8),
                    wd, x, iters=iters)
                for m in FIXED}
            seen[key] = fixed
            pe = plan[op.name]
            best_fixed = min(fixed.values())
            lines.append(row(
                f"fig12/{name}/{op.name}", pe.est_s,
                f"method={pe.method};tm={pe.tm or '-'};"
                f"te={pe.te or '-'};tf={pe.tf or '-'};"
                f"pad_to={pe.pad_to or '-'};fuse={int(pe.fuse)};"
                f"stride={op.stride};"
                f"speedup_vs_dense={fixed['dense'] / pe.est_s:.2f};"
                f"speedup_vs_best_fixed={best_fixed / pe.est_s:.2f}"))
        for m in FIXED:
            totals[m] += fixed[m]
        t_tuned += plan[op.name].est_s
    for m in FIXED:
        lines.append(row(f"fig12/{name}/total/{m}", totals[m],
                         f"tuned_speedup={totals[m] / t_tuned:.2f}"))
    lines.append(row(f"fig12/{name}/total/auto", t_tuned,
                     f"speedup_vs_dense={totals['dense'] / t_tuned:.2f}"))
    return lines


def run() -> List[str]:
    lines = []
    for name in SCALES:
        lines += bench_model(name)
    return lines
