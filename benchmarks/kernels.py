"""Kernel-level microbenchmarks + Pallas-vs-oracle verification counts.

Interpret-mode Pallas wall time is meaningless (Python execution), so for the
kernels this reports correctness sweeps + the *structural* performance model:
per-grid-cell VMEM bytes and FLOPs (what the Mosaic pipeline would stream).
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import (bcsr_from_dense, block_prune, ell_from_dense_conv,
                        magnitude_prune)
from repro.kernels.bsr_matmul.ops import bsr_matmul, choose_tb
from repro.kernels.bsr_matmul.ref import bsr_matmul_ref
from repro.kernels.sparse_conv.ops import choose_tm, sparse_conv
from repro.kernels.sparse_conv.ref import sparse_conv_ref


def run() -> List[str]:
    out = []
    rng = np.random.default_rng(0)
    # sparse_conv: AlexNet conv2-like geometry
    x = jnp.asarray(rng.standard_normal((1, 96, 31, 31)).astype(np.float32))
    w = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((256, 96, 5, 5)).astype(np.float32)),
        0.62))
    ell = ell_from_dense_conv(w)
    got = sparse_conv(x, ell, padding=0, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(w))
    err = float(jnp.max(jnp.abs(got - ref.astype(got.dtype))))
    tm = choose_tm(256, 96, 31, 31, 27, 27, ell.k)
    vmem = 96 * 31 * 31 * 4 + tm * ell.k * 4 + tm * 27 * 27 * 4
    out.append(row("kernels/sparse_conv/alexnet_conv2", 0.0,
                   f"max_err={err:.1e};tm={tm};vmem_bytes={vmem};k={ell.k}"))
    # bsr_matmul: FFN-like geometry
    wl = np.asarray(block_prune(
        jnp.asarray(rng.standard_normal((512, 1024)).astype(np.float32)),
        0.75, (128, 128)))
    bc = bcsr_from_dense(wl, (128, 128))
    xb = jnp.asarray(rng.standard_normal((256, 1024)).astype(np.float32))
    got = bsr_matmul(xb, bc, interpret=True)
    ref = bsr_matmul_ref(xb, bc)
    err = float(jnp.max(jnp.abs(got - ref.astype(got.dtype))))
    dense_tiles = int(np.asarray(bc.nblocks).sum())
    total_tiles = (512 // 128) * (1024 // 128)
    out.append(row(
        "kernels/bsr_matmul/ffn_512x1024_s0.75", 0.0,
        f"max_err={err:.1e};mxu_tiles={dense_tiles}/{total_tiles};"
        f"flop_saving={1 - dense_tiles / total_tiles:.2f};"
        f"tb={choose_tb(256, 128, 128, 4)}"))
    return out
