"""Fault-tolerant CNN serving tier tests: admission control, the
degradation ladder, retry/backoff classification, and the seeded chaos
harness's zero-lost acceptance bar."""
import numpy as np
import pytest

from repro import telemetry
from repro.engine import init_conv_params, lower
from repro.runtime.fault_tolerance import Backoff
from repro.serving import (REJECT_REASONS, BucketSpec, ChaosConfig,
                           ChaosFatalError, ChaosInjector,
                           ChaosRetryableError, InferenceRequest,
                           RobustCnnServer, VirtualClock, arrival_trace,
                           corrupt_plan_cache_file, slice_net)

NETS = ("alexnet", "googlenet", "resnet50")


class ScriptedChaos:
    """Chaos stand-in with a scripted fault sequence: deterministic tests
    drive exact retry/escalate paths through the production machinery."""

    def __init__(self, faults=()):
        self.faults = list(faults)

    def draw_step_fault(self):
        return self.faults.pop(0) if self.faults else None

    def inflate_tick(self, dt):
        return dt, False

    def corrupt_plan(self, plan, program):
        return plan


@pytest.fixture(scope="module")
def alex():
    net = slice_net("alexnet")
    params = init_conv_params(lower(net, (3, 12, 12)),
                              np.random.default_rng(0))
    return net, params


def _server(alex, **kw):
    net, params = alex
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("queue_depth", 8)
    buckets = kw.pop("buckets", [BucketSpec(3, 12, 12, batch=2)])
    return RobustCnnServer(net, params, buckets, **kw)


def _req(rid, shape=(3, 12, 12), **kw):
    return InferenceRequest(rid=rid, shape=shape, **kw)


# -- ladder construction ----------------------------------------------------

@pytest.mark.parametrize("name", NETS)
def test_ladder_builds_and_verifies_clean(name):
    net = slice_net(name)
    params = init_conv_params(lower(net, (3, 12, 12)),
                              np.random.default_rng(0))
    srv = RobustCnnServer(net, params, [BucketSpec(3, 12, 12, batch=2)],
                          clock=VirtualClock())
    (bucket,) = srv._buckets
    names = [r.name for r in bucket.rungs]
    assert names[0] == "tuned" and names[-1] == "dense"
    assert not srv.dropped_rungs
    for rung in bucket.rungs:
        # Every served rung passed the static gate: no silent fallbacks.
        assert rung.report.fallback_count == 0
        assert rung.report.rung == rung.name
        assert rung.est_s > 0


def test_quantised_rung_narrows_sparse_entries(alex):
    srv = _server(alex)
    (bucket,) = srv._buckets
    by_name = {r.name: r for r in bucket.rungs}
    if "quantised" in by_name:
        q = by_name["quantised"].plan
        assert any(pe.value_dtype == "int8" for pe in q.values()
                   if pe.method in ("pallas", "bsr"))
    dense = by_name["dense"].plan
    assert all(pe.method == "dense" for pe in dense.values())


def test_corrupted_plan_drops_rung_not_service(alex):
    """A chaos-corrupted (statically infeasible) tuned plan is caught by
    the build-time verifier: the rung is dropped, traffic runs the next
    rung down, nothing is lost."""
    chaos = ChaosInjector(ChaosConfig(seed=0, plan_corruption_rate=1.0))
    srv = _server(alex, chaos=chaos)
    (bucket,) = srv._buckets
    assert chaos.corrupted_entries
    assert srv.dropped_rungs
    assert all(d["preflight_errors"] or d["fallback_reasons"]
               for d in srv.dropped_rungs)
    assert "tuned" not in [r.name for r in bucket.rungs]
    rep = srv.run_trace(arrival_trace(6, [(3, 12, 12)], seed=1)).verify()
    assert rep.completed == 6


# -- admission control ------------------------------------------------------

def test_rejection_no_bucket(alex):
    srv = _server(alex)
    r = _req(0, shape=(1, 12, 12))  # channel count no bucket serves
    assert srv.submit(r) is False
    assert r.status == "rejected" and r.reject_reason == "no_bucket"


def test_rejection_queue_full(alex):
    srv = _server(alex, queue_depth=2)
    rs = [_req(i) for i in range(4)]
    admitted = [srv.submit(r) for r in rs]
    assert admitted == [True, True, False, False]
    assert rs[2].reject_reason == rs[3].reject_reason == "queue_full"
    assert all(r in REJECT_REASONS for r in ("queue_full", "no_bucket"))


def test_rejection_deadline_expired(alex):
    srv = _server(alex)
    r = _req(0, deadline_s=0.001)
    srv.submit(r)
    srv.clock.advance(1.0)  # deadline passes while queued
    srv.tick()
    assert r.status == "rejected" and r.reject_reason == "deadline_expired"


def test_smaller_shapes_pad_into_bucket(alex):
    srv = _server(alex)
    x = np.random.default_rng(0).standard_normal((3, 10, 10)).astype(
        np.float32)
    r = InferenceRequest(rid=0, x=x)
    srv.submit(r)
    srv.tick()
    assert r.status == "done" and r.result is not None
    assert r.bucket == "3x12x12b2"


def test_drain_exhausted_rejects_leftovers(alex):
    srv = _server(alex)
    trace = arrival_trace(10, [(3, 12, 12)], seed=0, mean_gap_s=0.0,
                          deadline_s=None)
    rep = srv.run_trace(trace, max_ticks=2).verify()  # budget too small
    assert rep.rejected.get("drain_exhausted", 0) > 0
    assert rep.lost == 0


# -- retry / failure classification -----------------------------------------

def test_retryable_fault_retries_then_completes(alex):
    srv = _server(alex, chaos=ScriptedChaos([
        ChaosRetryableError("UNAVAILABLE: injected (chaos)")]))
    r = _req(0)
    srv.submit(r)
    srv.tick()                      # faulted dispatch -> re-enqueued
    assert r.status == "queued" and r.attempts == 1
    assert r.not_before_s > srv.clock.now() - 1e-9
    srv.clock.advance(srv.backoff.delay_s(0))
    srv.tick()                      # backoff expired -> served
    assert r.status == "done"
    rep = srv.slo_report()
    assert rep.retries == 1 and rep.lost == 0


def test_retries_exhausted_rejects(alex):
    faults = [ChaosRetryableError("UNAVAILABLE: injected (chaos)")] * 5
    srv = _server(alex, chaos=ScriptedChaos(faults), max_attempts=2)
    r = _req(0)
    srv.submit(r)
    srv.tick()
    srv.clock.advance(10.0)
    srv.tick()
    assert r.status == "rejected" and r.reject_reason == "retries_exhausted"


def test_fatal_fault_rejects_immediately(alex):
    srv = _server(alex, chaos=ScriptedChaos([
        ChaosFatalError("injected device loss (chaos)")]))
    r = _req(0)
    srv.submit(r)
    srv.tick()
    assert r.status == "rejected" and r.reject_reason == "fatal_error"
    assert srv.slo_report().lost == 0


def test_backoff_policy_deterministic_and_capped():
    b = Backoff(base_s=0.1, mult=2.0, cap_s=0.5)
    assert [b.delay_s(i) for i in range(4)] == [0.1, 0.2, 0.4, 0.5]
    with pytest.raises(ValueError):
        Backoff(base_s=0.0)


# -- the degradation ladder at runtime --------------------------------------

def test_escalating_faults_step_down_then_recover(alex):
    """max_strikes consecutive retryable faults escalate: the bucket steps
    down a rung; a cool-down of healthy ticks steps it back up."""
    faults = [ChaosRetryableError("UNAVAILABLE: injected (chaos)")] * 3
    srv = _server(alex, chaos=ScriptedChaos(faults), max_strikes=3,
                  max_attempts=10, cooldown_ticks=2,
                  backoff=Backoff(base_s=0.001))
    (bucket,) = srv._buckets
    assert len(bucket.rungs) >= 2
    top = bucket.rungs[0].name
    r = _req(0)
    srv.submit(r)
    for _ in range(3):              # three strikes -> escalate
        srv.tick()
        srv.clock.advance(1.0)
    downs = [e for e in srv.events if e.reason == "escalate"]
    assert len(downs) == 1 and downs[0].from_rung == top
    assert bucket.rung_idx == 1
    # healthy ticks at the degraded rung recover the ladder
    srv.tick()                      # serves r at the degraded rung
    assert r.status == "done" and r.rung == bucket.rungs[1].name
    for i in range(3):
        r2 = _req(10 + i)
        srv.submit(r2)
        srv.tick()
    ups = [e for e in srv.events if e.reason == "recovered"]
    assert len(ups) == 1 and ups[0].to_rung == top
    assert bucket.rung_idx == 0


def test_overload_steps_down(alex):
    srv = _server(alex, queue_depth=4, high_water=0.5, cooldown_ticks=100)
    for i in range(4):
        srv.submit(_req(i))
    srv.tick()
    assert any(e.reason == "overload" for e in srv.events)


def test_rung_recorded_on_reports_and_requests(alex):
    srv = _server(alex)
    (bucket,) = srv._buckets
    r = _req(0)
    srv.submit(r)
    with telemetry.enabled():
        srv.tick()
        report = bucket.engine.last_report
    telemetry.reset()
    assert r.rung == bucket.rungs[0].name
    assert report.rung == r.rung
    assert report.to_dict()["rung"] == r.rung
    assert f"rung={r.rung}" in report.format()


# -- chaos acceptance -------------------------------------------------------

@pytest.mark.parametrize("name", NETS)
def test_heavy_chaos_trace_loses_nothing(name):
    """The acceptance bar: under seeded step faults, plan corruption, and
    stragglers, a heavy-traffic trace terminates every request exactly
    once, with machine-readable reasons on every rejection."""
    net = slice_net(name)
    params = init_conv_params(lower(net, (3, 12, 12)),
                              np.random.default_rng(0))
    chaos = ChaosInjector(ChaosConfig(
        seed=0, step_fault_rate=0.35, plan_corruption_rate=0.5,
        straggler_rate=0.2))
    srv = RobustCnnServer(net, params, [BucketSpec(3, 12, 12, batch=2)],
                          clock=VirtualClock(), queue_depth=16,
                          max_attempts=6, chaos=chaos)
    trace = arrival_trace(20, [(3, 12, 12), (3, 10, 10)], seed=2,
                          mean_gap_s=0.0005, deadline_s=(1.0, 2.0))
    rep = srv.run_trace(trace).verify()
    assert rep.submitted == 20
    assert rep.degradations or rep.dropped_rungs
    for r in srv.requests:
        assert r.status in ("done", "rejected")
        if r.status == "rejected":
            assert r.reject_reason in REJECT_REASONS
        else:
            assert r.rung is not None and r.result is not None


def test_chaos_replays_identically(alex):
    """Same seed, same workload -> identical SLO summary (the property the
    whole harness exists for)."""
    def run():
        srv = _server(alex, chaos=ChaosInjector(ChaosConfig(
            seed=5, step_fault_rate=0.4, straggler_rate=0.3)),
            max_attempts=6, queue_depth=16)
        trace = arrival_trace(15, [(3, 12, 12)], seed=3, mean_gap_s=0.001)
        return srv.run_trace(trace).verify().to_dict()

    assert run() == run()


def test_straggler_ticks_observed(alex):
    chaos = ChaosInjector(ChaosConfig(seed=1, straggler_rate=0.3,
                                      straggler_factor=50.0))
    srv = _server(alex, chaos=chaos, queue_depth=32)
    trace = arrival_trace(30, [(3, 12, 12)], seed=4, mean_gap_s=0.0,
                          deadline_s=None)
    rep = srv.run_trace(trace).verify()
    assert chaos.injected_stragglers > 0
    assert rep.straggler_ticks > 0


def test_telemetry_counters_namespaced(alex):
    telemetry.reset()
    with telemetry.enabled():
        srv = _server(alex, queue_depth=2)
        for i in range(4):
            srv.submit(_req(i, deadline_s=None))
        while srv.pending():
            srv.tick()
        snap = telemetry.snapshot()
    telemetry.reset()
    assert snap["serving.cnn.submitted"]["value"] == 4
    assert snap["serving.cnn.admitted"]["value"] == 2
    assert snap["serving.cnn.completed"]["value"] == 2
    assert snap["serving.cnn.rejected"]["value"] == 2
    assert snap["serving.cnn.rejected.queue_full"]["value"] == 2


def test_chaos_off_records_nothing(alex):
    telemetry.reset()
    srv = _server(alex)
    srv.submit(_req(0))
    srv.tick()
    assert telemetry.snapshot() == {}  # zero-overhead-when-off discipline


# -- plan-cache corruption seam ---------------------------------------------

@pytest.mark.parametrize("mode", ("garbage", "truncate", "bad_entry"))
def test_corrupt_plan_cache_degrades_resiliently(tmp_path, mode, alex):
    from repro.tuning import PlanCache, plan_program
    from repro.tuning.cache import PlanCacheWarning

    net, params = alex
    program = lower(net, (3, 12, 12))
    path = str(tmp_path / "plans.json")
    plan_program(program, batch=2, mode="roofline", cache=PlanCache(path),
                 params=params)
    corrupt_plan_cache_file(path, mode=mode)
    with pytest.warns(PlanCacheWarning):
        srv = RobustCnnServer(net, params, [BucketSpec(3, 12, 12, batch=2)],
                              plan_cache=path, clock=VirtualClock())
    rep = srv.run_trace(arrival_trace(4, [(3, 12, 12)], seed=0)).verify()
    assert rep.completed == 4
