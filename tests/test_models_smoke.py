"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.launch.steps import init_state, make_train_step

ARCHS = cfgs.list_archs()


def _batch(cfg, b=2, t=16, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab, jnp.int32)
    labels = jnp.roll(toks, -1, axis=1)
    if cfg.family in ("vlm", "encoder"):
        emb = jax.random.normal(key, (b, t, cfg.d_model), jnp.bfloat16)
        return {"embeds": emb, "labels": labels}
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = cfgs.get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    if "embeds" in batch:
        logits, _ = jax.jit(lambda p, e: T.forward_embeds(p, e, cfg))(
            params, batch["embeds"])
    else:
        logits, _ = jax.jit(lambda p, t: T.forward(p, t, cfg))(
            params, batch["tokens"])
    b, t = batch["labels"].shape
    assert logits.shape == (b, t, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = cfgs.get_config(arch, smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=10))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    p0 = jax.tree.leaves(state["params"])[0]
    assert not np.isnan(np.asarray(p0, np.float32)).any()
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if cfgs.REGISTRY[a].FAMILY != "encoder"])
def test_smoke_decode_step(arch):
    cfg = cfgs.get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    cache = T.init_cache(cfg, b, 32)
    step = jax.jit(lambda p, t, c, l: T.decode_step(p, cfg, t, c, l))
    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab, jnp.int32)
    for i in range(3):
        logits, cache = step(params, tok, cache, jnp.int32(i))
        assert logits.shape == (b, cfg.vocab)
        assert not np.isnan(np.asarray(logits, np.float32)).any()
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_full_configs_match_assignment():
    """Exact published sizes from the assignment brief."""
    c = cfgs.get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129280)
    assert (c.n_experts, c.top_k, c.moe_d_ff) == (256, 8, 2048)
    assert c.use_mla and c.mtp_depth == 1 and c.n_shared_experts == 1
    c = cfgs.get_config("olmoe-1b-7b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (16, 2048, 64, 8)
    c = cfgs.get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (72, 8192, 64, 8)
    assert (c.n_experts, c.top_k, c.attn_period) == (16, 2, 8)
    c = cfgs.get_config("qwen1.5-0.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        24, 1024, 16, 2816, 151936)
    assert c.qkv_bias
    c = cfgs.get_config("qwen1.5-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (40, 2560, 20, 6912)
    c = cfgs.get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        88, 12288, 96, 8, 28672, 32768)
    c = cfgs.get_config("yi-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        48, 4096, 32, 4, 11008, 64000)
    c = cfgs.get_config("hubert-xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        48, 1280, 16, 5120, 504)
    assert not c.causal
    c = cfgs.get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab) == (64, 2560, 128, 50280)
    assert c.n_heads == 0
    c = cfgs.get_config("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        32, 3072, 32, 8192, 32064)


def test_param_counts_plausible():
    """num_params() approximations land near the published sizes."""
    expect = {
        "deepseek-v3-671b": (6.0e11, 7.6e11),
        "olmoe-1b-7b": (6.0e9, 8.0e9),
        "mistral-large-123b": (1.1e11, 1.35e11),
        "yi-9b": (8.0e9, 1.0e10),
        "qwen1.5-0.5b": (4.0e8, 7.5e8),
        "mamba2-2.7b": (2.3e9, 3.2e9),
        "phi-3-vision-4.2b": (3.5e9, 4.5e9),
        "jamba-1.5-large-398b": (3.4e11, 4.4e11),
    }
    for arch, (lo, hi) in expect.items():
        n = cfgs.get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_below_total():
    c = cfgs.get_config("deepseek-v3-671b")
    assert c.active_params() < 0.1 * c.num_params()


def test_stage_plans():
    from repro.models.transformer import stage_plan
    pre, period, n = stage_plan(cfgs.get_config("deepseek-v3-671b"))
    assert len(pre) == 3 and len(period) == 1 and n == 58
    pre, period, n = stage_plan(cfgs.get_config("jamba-1.5-large-398b"))
    assert len(pre) == 0 and len(period) == 8 and n == 9
    kinds = [d.kind for d in period]
    assert kinds.count("attn") == 1 and kinds[7] == "attn"
    assert sum(d.ffn == "moe" for d in period) == 4
