"""Format round-trips + CSR semantics (paper Fig. 4) + hypothesis properties."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip module on clean envs
from hypothesis import given, settings, strategies as st

from repro.core import (balance_ell_conv, bcsr_conv_from_dense,
                        bcsr_conv_to_dense, bcsr_from_dense, bcsr_to_dense,
                        block_prune_conv, csr_arrays_from_dense,
                        ell_from_dense, ell_from_dense_conv, ell_to_dense,
                        inverse_permutation, magnitude_prune, block_prune,
                        stretch_offsets)
from repro.core.sparse_format import bcsr_stack_from_dense


def _pruned(rng, shape, sparsity=0.8):
    w = rng.standard_normal(shape).astype(np.float32)
    return np.asarray(magnitude_prune(jnp.asarray(w), sparsity))


def test_csr_matches_paper_example():
    # Fig. 4 of the paper.
    m = np.array([
        [10, 20, 0, 0, 0, 0],
        [0, 30, 0, 40, 0, 0],
        [0, 0, 50, 60, 70, 0],
        [0, 0, 0, 0, 0, 80],
    ], dtype=np.float32)
    value, colidx, rowptr = csr_arrays_from_dense(m)
    np.testing.assert_array_equal(value, [10, 20, 30, 40, 50, 60, 70, 80])
    np.testing.assert_array_equal(rowptr, [0, 2, 4, 7, 8])
    np.testing.assert_array_equal(colidx, [0, 1, 1, 3, 2, 3, 4, 5])


def test_ell_roundtrip():
    rng = np.random.default_rng(0)
    w = _pruned(rng, (37, 53))
    np.testing.assert_allclose(np.asarray(ell_to_dense(ell_from_dense(w))), w)


def test_bcsr_roundtrip():
    rng = np.random.default_rng(1)
    w = np.asarray(block_prune(
        jnp.asarray(rng.standard_normal((130, 70)).astype(np.float32)),
        0.6, (16, 8)))
    np.testing.assert_allclose(
        np.asarray(bcsr_to_dense(bcsr_from_dense(w, (16, 8)))), w)


def test_bcsr_stack_roundtrip():
    rng = np.random.default_rng(2)
    ws = np.stack([
        np.asarray(block_prune(
            jnp.asarray(rng.standard_normal((64, 48)).astype(np.float32)),
            s, (16, 16)))
        for s in (0.2, 0.8)])  # different nnz per layer -> padding path
    stacked = bcsr_stack_from_dense(ws, (16, 16))
    import jax
    for i in range(2):
        layer = jax.tree.map(lambda a: a[i], stacked)
        np.testing.assert_allclose(np.asarray(bcsr_to_dense(layer)), ws[i])


def test_weight_stretching_formula():
    # off = (c*Hp + r)*Wp + s  — the paper's layout function f.
    rng = np.random.default_rng(3)
    w = _pruned(rng, (4, 3, 3, 3), 0.5)
    ell = stretch_offsets(ell_from_dense_conv(w), hp=10, wp=7)
    off = np.asarray(ell.offset)
    c, r, s = np.asarray(ell.cidx), np.asarray(ell.ridx), np.asarray(ell.sidx)
    np.testing.assert_array_equal(off, (c * 10 + r) * 7 + s)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.integers(2, 40),
       st.floats(0.0, 0.95), st.integers(0, 1000))
def test_ell_roundtrip_property(m, n, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = _pruned(rng, (m, n), sparsity)
    np.testing.assert_allclose(np.asarray(ell_to_dense(ell_from_dense(w))), w)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 1000))
def test_bcsr_roundtrip_property(gm, gn, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((gm * 8 + 3, gn * 8 + 5)).astype(np.float32)
    w[rng.random(w.shape) < 0.7] = 0.0
    np.testing.assert_allclose(
        np.asarray(bcsr_to_dense(bcsr_from_dense(w, (8, 8)))), w)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 0.95), st.integers(0, 1000))
def test_magnitude_prune_achieves_sparsity(sparsity, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    p = np.asarray(magnitude_prune(jnp.asarray(w), sparsity))
    achieved = (p == 0).mean()
    assert abs(achieved - sparsity) < 0.05
    # surviving entries are untouched
    np.testing.assert_array_equal(p[p != 0], w[p != 0])


def _ell_conv_to_dense(ell):
    """Scatter an EllConv (possibly row-permuted) back to (M, C, R, S)."""
    m, c, r, s = ell.shape
    out = np.zeros((m, c, r, s), np.float32)
    rows = np.asarray(ell.perm) if ell.perm is not None else np.arange(m)
    val = np.asarray(ell.value)
    cid, rid, sid = (np.asarray(a) for a in (ell.cidx, ell.ridx, ell.sidx))
    nnz = np.asarray(ell.nnz)
    for i in range(m):
        for j in range(nnz[i]):
            out[rows[i], cid[i, j], rid[i, j], sid[i, j]] += val[i, j]
    return out


def test_balanced_bank_roundtrip():
    """balance_ell_conv permutes whole rows only: scattering the balanced
    bank through its perm reconstructs the exact original filter bank, rows
    are sorted by descending nnz, and perm is a valid permutation."""
    rng = np.random.default_rng(11)
    w = _pruned(rng, (16, 4, 3, 3), 0.7)
    ell = ell_from_dense_conv(w)
    bal = balance_ell_conv(ell)
    assert ell.perm is None and bal.perm is not None
    perm = np.asarray(bal.perm)
    assert sorted(perm.tolist()) == list(range(16))
    nnz = np.asarray(bal.nnz)
    assert (np.diff(nnz) <= 0).all()
    np.testing.assert_array_equal(_ell_conv_to_dense(bal), w)
    # inverse_permutation really inverts
    inv = np.asarray(inverse_permutation(bal.perm))
    np.testing.assert_array_equal(perm[inv], np.arange(16))
    # per-row contents are untouched (row i of bal == row perm[i] of ell)
    np.testing.assert_array_equal(np.asarray(bal.value),
                                  np.asarray(ell.value)[perm])


def test_balance_via_ell_from_dense_conv_flag():
    rng = np.random.default_rng(13)
    w = _pruned(rng, (8, 3, 3, 3), 0.6)
    bal = ell_from_dense_conv(w, balance=True)
    assert bal.perm is not None
    np.testing.assert_array_equal(_ell_conv_to_dense(bal), w)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 24), st.floats(0.0, 0.95), st.integers(0, 1000))
def test_balanced_bank_roundtrip_property(m, sparsity, seed):
    rng = np.random.default_rng(seed)
    w = _pruned(rng, (m, 3, 3, 3), sparsity)
    bal = balance_ell_conv(ell_from_dense_conv(w))
    np.testing.assert_array_equal(_ell_conv_to_dense(bal), w)
    nnz = np.asarray(bal.nnz)
    assert (np.diff(nnz) <= 0).all()


# ---------------------------------------------------------------------------
# BCSR property coverage: from_dense / to_dense / stack round-trips
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 70), st.integers(1, 70), st.integers(1, 16),
       st.integers(1, 16), st.integers(1, 4), st.floats(0.0, 1.0),
       st.integers(0, 1000))
def test_bcsr_roundtrip_property_non_dividing(m, n, bm, bn, pad_to, density,
                                              seed):
    """Round-trip over arbitrary (shape, block, pad_to): non-dividing
    shapes, ragged per-row tile counts, and the all-zero matrix where KB
    clamps to 1.  KB must always be a pad_to multiple and at least the
    densest row's tile count."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, n)).astype(np.float32)
    w[rng.random(w.shape) >= density] = 0.0
    b = bcsr_from_dense(w, (bm, bn), pad_to=pad_to)
    np.testing.assert_allclose(np.asarray(bcsr_to_dense(b)), w)
    counts = np.asarray(b.nblocks)
    assert b.kb % pad_to == 0 and b.kb >= max(1, int(counts.max()))
    # padding tiles are inert: all-zero data
    blocks = np.asarray(b.blocks)
    for i in range(blocks.shape[0]):
        assert (blocks[i, counts[i]:] == 0).all()


def test_bcsr_all_zero_kb_clamps_to_one():
    b = bcsr_from_dense(np.zeros((17, 33), np.float32), (8, 8))
    assert b.kb == 1
    assert (np.asarray(b.nblocks) == 0).all()
    np.testing.assert_array_equal(np.asarray(bcsr_to_dense(b)), 0.0)


def test_bcsr_degenerate_pad_to_clamped():
    """pad_to < 1 is clamped instead of crashing (same contract as the ELL
    converters)."""
    b = bcsr_from_dense(np.zeros((4, 8), np.float32), (4, 4), pad_to=0)
    assert b.kb >= 1


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 40),
       st.integers(0, 1000))
def test_bcsr_stack_roundtrip_property(layers, m, n, seed):
    """Stacked layers with ragged per-layer tile counts pad to one common
    KB; slicing the leading axis recovers each layer exactly."""
    import jax
    rng = np.random.default_rng(seed)
    ws = np.stack([
        np.where(rng.random((m, n)) < rng.uniform(0.05, 0.9),
                 rng.standard_normal((m, n)), 0.0).astype(np.float32)
        for _ in range(layers)])
    stacked = bcsr_stack_from_dense(ws, (8, 8))
    for i in range(layers):
        layer = jax.tree.map(lambda a: a[i], stacked)
        np.testing.assert_allclose(np.asarray(bcsr_to_dense(layer)), ws[i])


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 20), st.integers(1, 6), st.integers(1, 3),
       st.sampled_from([(4, 8), (8, 16), (8, 128)]),
       st.floats(0.0, 0.95), st.integers(0, 1000))
def test_bcsr_conv_roundtrip_property(m, c, r, block, sparsity, seed):
    """BcsrConv round-trips any (block-pruned or not) filter bank through
    the flattened (M, C*R*S) blocked layout."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, c, r, r)).astype(np.float32)
    if sparsity > 0:
        w = np.asarray(block_prune_conv(jnp.asarray(w), sparsity, block))
    bc = bcsr_conv_from_dense(w, block=block)
    assert bc.shape == w.shape and bc.block == block
    np.testing.assert_allclose(np.asarray(bcsr_conv_to_dense(bc)), w)


def test_block_prune_conv_keeps_dense_tiles():
    """Surviving tiles of the flattened weight matrix stay fully dense —
    each maps to one MXU contraction."""
    rng = np.random.default_rng(5)
    w = rng.standard_normal((16, 8, 3, 3)).astype(np.float32) + 0.5
    p = np.asarray(block_prune_conv(jnp.asarray(w), 0.5, (8, 8)))
    flat = p.reshape(16, 72)
    padded = np.pad(flat, ((0, 0), (0, 8)))  # 72 -> 80 = 10 tiles of 8
    tiles = padded.reshape(2, 8, 10, 8).transpose(0, 2, 1, 3)
    for i in range(2):
        for j in range(9):  # last tile column is padding
            t = tiles[i, j]
            assert (t == 0).all() or (t != 0).all()


def test_block_prune_keeps_dense_tiles():
    rng = np.random.default_rng(4)
    w = rng.standard_normal((64, 64)).astype(np.float32) + 0.5
    p = np.asarray(block_prune(jnp.asarray(w), 0.5, (16, 16)))
    tiles = p.reshape(4, 16, 4, 16).transpose(0, 2, 1, 3)
    for i in range(4):
        for j in range(4):
            t = tiles[i, j]
            assert (t == 0).all() or (t != 0).all()

# ---------------------------------------------------------------------------
# Quantised value streams: int8 / fp8 banks with per-channel f32 scales
# ---------------------------------------------------------------------------

from repro.core.sparse_format import (QUANT_DTYPES, dequantize,  # noqa: E402
                                      quantize_values)


def _quant_err(w, value_dtype):
    """(abs error, per-channel scale broadcast to w) after a round-trip."""
    ell = ell_from_dense_conv(w)
    q = quantize_values(ell, value_dtype)
    assert q.value_dtype == value_dtype
    deq = dequantize(q)
    err = np.abs(_ell_conv_to_dense(deq) - w)
    scale = np.asarray(q.scale)
    return err, scale


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24), st.floats(0.0, 0.95), st.integers(0, 1000))
def test_quantize_int8_roundtrip_within_bound(m, sparsity, seed):
    """int8 round-trip error is elementwise <= s/2 per output channel — the
    documented round-to-nearest bound on w/s in [-127, 127]."""
    rng = np.random.default_rng(seed)
    w = _pruned(rng, (m, 3, 3, 3), sparsity)
    err, scale = _quant_err(w, "int8")
    bound = scale[:, None, None, None] * 0.5 * (1 + 1e-6) + 1e-12
    assert (err <= bound).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24), st.floats(0.0, 0.95), st.integers(0, 1000))
def test_quantize_fp8_roundtrip_within_bound(m, sparsity, seed):
    """fp8 e4m3 round-trip error is <= max(|w| * 2**-4, s * 2**-10): 3
    mantissa bits give 2**-4 relative error on normals, and subnormal
    quotients bottom out at an absolute s * 2**-10."""
    rng = np.random.default_rng(seed)
    w = _pruned(rng, (m, 3, 3, 3), sparsity)
    err, scale = _quant_err(w, "float8_e4m3fn")
    s = scale[:, None, None, None]
    bound = np.maximum(np.abs(w) * 2.0**-4, s * 2.0**-10) * (1 + 1e-5) + 1e-12
    assert (err <= bound).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 20), st.sampled_from(sorted(QUANT_DTYPES)),
       st.integers(0, 1000))
def test_quantize_per_channel_scales(m, value_dtype, seed):
    """Channel m's scale is exactly absmax_m / qmax (f32), computed over
    that channel's nonzeros alone — scaling one channel scales only its own
    scale entry."""
    rng = np.random.default_rng(seed)
    w = _pruned(rng, (m, 4, 3, 3), 0.6)
    q = quantize_values(ell_from_dense_conv(w), value_dtype)
    absmax = np.abs(w).max(axis=(1, 2, 3)).astype(np.float32)
    qmax = np.float32(QUANT_DTYPES[value_dtype])
    expect = np.where(absmax > 0, absmax / qmax, np.float32(1.0))
    np.testing.assert_array_equal(np.asarray(q.scale), expect)


@pytest.mark.parametrize("value_dtype", sorted(QUANT_DTYPES))
def test_quantize_all_zero_bank_exact(value_dtype):
    """All-zero channels take scale 1 and round-trip to exact zeros — no
    division by zero, no denormal dust."""
    w = np.zeros((6, 3, 3, 3), np.float32)
    q = quantize_values(ell_from_dense_conv(w), value_dtype)
    np.testing.assert_array_equal(np.asarray(q.scale), 1.0)
    assert (np.asarray(dequantize(q).value) == 0.0).all()
    bq = quantize_values(bcsr_conv_from_dense(w, block=(4, 8)), value_dtype)
    np.testing.assert_array_equal(np.asarray(bq.scale), 1.0)
    assert (np.asarray(dequantize(bq).blocks) == 0.0).all()


def test_quantize_already_quantised_raises():
    w = np.random.default_rng(7).standard_normal((4, 2, 3, 3)).astype(
        np.float32)
    q = quantize_values(ell_from_dense_conv(w), "int8")
    with pytest.raises(ValueError, match="already quantised"):
        quantize_values(q, "int8")
    bq = quantize_values(bcsr_conv_from_dense(w, block=(4, 8)), "int8")
    with pytest.raises(ValueError, match="already quantised"):
        quantize_values(bq, "float8_e4m3fn")


def test_quantize_unknown_dtype_raises():
    w = np.zeros((2, 1, 1, 1), np.float32)
    with pytest.raises(ValueError, match="unsupported quantised value"):
        quantize_values(ell_from_dense_conv(w), "int4")


def test_dequantize_passthrough_on_f32_banks():
    rng = np.random.default_rng(9)
    w = _pruned(rng, (8, 3, 3, 3), 0.5)
    ell = ell_from_dense_conv(w)
    assert dequantize(ell) is ell and ell.value_dtype == "float32"
    bc = bcsr_conv_from_dense(w, block=(4, 8))
    assert dequantize(bc) is bc and bc.value_dtype == "float32"


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4),
       st.sampled_from(sorted(QUANT_DTYPES)), st.integers(0, 1000))
def test_quantize_bcsr_roundtrip_within_bound(m, c, value_dtype, seed):
    """BcsrConv quantisation respects the same per-channel bounds, with the
    scale of flattened row i living at scale[i // bm, i % bm]; padding tiles
    stay exactly zero."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, c, 3, 3)).astype(np.float32)
    w = np.asarray(block_prune_conv(jnp.asarray(w), 0.5, (4, 8)))
    bc = bcsr_conv_from_dense(w, block=(4, 8))
    q = quantize_values(bc, value_dtype)
    assert q.value_dtype == value_dtype
    err = np.abs(np.asarray(bcsr_conv_to_dense(dequantize(q))) - w)
    s = np.asarray(q.scale).reshape(-1)[:m][:, None, None, None]
    if value_dtype == "int8":
        bound = s * 0.5 * (1 + 1e-6) + 1e-12
    else:
        bound = np.maximum(np.abs(w) * 2.0**-4, s * 2.0**-10) \
            * (1 + 1e-5) + 1e-12
    assert (err <= bound).all()
    # padding tiles past each block-row's nblocks stay inert zeros
    blocks, counts = np.asarray(q.blocks), np.asarray(q.nblocks)
    for i in range(blocks.shape[0]):
        assert (blocks[i, counts[i]:].astype(np.float32) == 0).all()
