import os
import sys

import pytest

# Tests run single-device (the dry-run alone forces 512 host devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _reset_model_flags():
    yield
    from repro.models import flags as F
    F.REMAT, F.UNROLL, F.ATTN_CHUNK, F.MOE_CAPACITY = "none", False, 1024, 1.25
