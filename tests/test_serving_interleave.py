"""Randomized interleaving invariants for the continuous batcher.

Drives ``ContinuousBatcher``/``ServeEngine`` through randomized
submit/tick/drain interleavings with a trivial pure-numpy serve step (no
model, no jit — the scheduling policy is what's under test) and checks the
three invariants the slot design promises:

  * no slot double-occupancy: a request is never live in two slots;
  * exactly-once termination: every submitted request ends finished or
    rejected, and appears exactly once in the drained result;
  * monotonic KV cursor: the shared write position never regresses while
    any slot is live (it resets only when the batch fully drains).

Property-based when Hypothesis is installed; a seeded-random sweep of the
same property otherwise (the container may not ship hypothesis — the
sweep keeps the invariants exercised either way).
"""
import numpy as np
import pytest

from repro.serving import Request, ServeEngine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False

MAX_LEN = 12


def _fake_step(params, toks, cache, cur_len):
    # Echo step: next token = first token + 1.  Shape-faithful to the real
    # serve_step contract ((B,1) tokens in, (B,) next out), nothing else.
    return np.asarray(toks)[:, 0] + 1, cache


def _engine(n_slots: int) -> ServeEngine:
    return ServeEngine(_fake_step, params=None, cache=None,
                       n_slots=n_slots, max_len=MAX_LEN)


def _check_interleaving(n_slots, schedule):
    """Run one submit/tick schedule and assert the batcher invariants at
    every step.  ``schedule`` is a list of (prompt_len, max_new) submits
    (None entries are ticks)."""
    eng = _engine(n_slots)
    submitted = []
    prev_cursor = 0
    rid = 0
    for item in schedule:
        if item is None:
            eng.tick()
        else:
            prompt_len, max_new = item
            req = Request(rid, list(range(1, prompt_len + 1)),
                          max_new_tokens=max_new)
            rid += 1
            submitted.append(req)
            eng.submit(req)
            eng.tick()
        # No double occupancy: a request never holds two slots.
        live = [s.request for s in eng.batcher.slots if s.request is not None]
        assert len(live) == len(set(map(id, live))), "slot double-occupancy"
        # Monotonic cursor: regress only via the reset-on-drain to zero.
        cur = eng._cursor
        assert cur >= prev_cursor or (cur == 0 and eng.batcher.active == 0), (
            f"KV cursor regressed {prev_cursor} -> {cur} with live slots")
        prev_cursor = cur
    result = eng.run_until_drained()
    assert result.drained, "fake-step drain must always complete"
    # Exactly-once termination: every request finished or rejected; the
    # drain result holds no duplicates and nothing that wasn't submitted
    # (requests retired during the manual tick phase are already done and
    # correctly absent from the drain's finished list).
    rids = [r.rid for r in result]
    assert len(rids) == len(set(rids)), "request surfaced twice"
    assert set(rids) <= {r.rid for r in submitted}
    for req in submitted:
        assert req.done
        oversize = len(req.prompt) + req.max_new_tokens > MAX_LEN
        if oversize:
            assert req.output == []  # rejected: never generated
        else:
            assert len(req.output) == req.max_new_tokens


def _random_schedule(rng) -> tuple:
    n_slots = int(rng.integers(1, 4))
    ops = []
    for _ in range(int(rng.integers(1, 20))):
        if rng.random() < 0.4:
            ops.append(None)  # tick
        else:
            # prompt+budget occasionally exceeds MAX_LEN: the rejection
            # path must also terminate exactly once.
            ops.append((int(rng.integers(1, 9)), int(rng.integers(1, 7))))
    return n_slots, ops


def test_interleavings_seeded_sweep():
    for seed in range(40):
        rng = np.random.default_rng(seed)
        n_slots, ops = _random_schedule(rng)
        _check_interleaving(n_slots, ops)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_interleavings_property_based():
    op = st.one_of(
        st.none(),
        st.tuples(st.integers(min_value=1, max_value=8),
                  st.integers(min_value=1, max_value=6)))

    @settings(max_examples=60, deadline=None)
    @given(n_slots=st.integers(min_value=1, max_value=3),
           schedule=st.lists(op, min_size=1, max_size=24))
    def run(n_slots, schedule):
        _check_interleaving(n_slots, schedule)

    run()
