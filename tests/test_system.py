"""End-to-end behaviour tests: train->improve, prune->serve, CNN inference
agreement across all execution methods (the paper's core contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import sparsify_params
from repro.launch.steps import init_state, make_serve_step, make_train_step
from repro.models import cnn
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig

TINY_LM = ModelConfig(name="sys-lm", family="dense", n_layers=2, d_model=128,
                      vocab=256, n_heads=4, n_kv_heads=4, head_dim=32,
                      d_ff=256, dtype="float32")


def test_training_reduces_loss_on_learnable_data():
    """Deterministic repeating pattern: CE must approach 0-ish quickly."""
    cfg = TINY_LM
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=60),
                   donate_argnums=(0,))
    toks = jnp.tile(jnp.arange(32, dtype=jnp.int32), (4, 4))  # period-32 text
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(60):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.3 * losses[0], (losses[0], losses[-1])


def test_prune_then_serve_pipeline():
    cfg = TINY_LM
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    sparse = sparsify_params(params, cfg, 0.6, block=(16, 16), min_dim=64)
    # at least one leaf must have been converted
    from repro.core.sparse_format import BcsrMatrix
    leaves = jax.tree.leaves(
        sparse, is_leaf=lambda x: isinstance(x, BcsrMatrix))
    assert any(isinstance(l, BcsrMatrix) for l in leaves)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
    cache = T.init_cache(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(8):
        nxt, cache = serve(sparse, tok, cache, jnp.int32(i))
        tok = nxt[:, None]
    assert np.isfinite(np.asarray(tok)).all()


def test_sparse_serving_matches_dense_predictions():
    """Low sparsity -> pruned model's decode outputs stay close to dense."""
    cfg = TINY_LM
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    sparse = sparsify_params(params, cfg, 0.03, block=(8, 8), min_dim=64)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab,
                              jnp.int32)
    dense_logits, _ = T.forward(params, toks, cfg)
    sparse_logits, _ = T.forward(sparse, toks, cfg)
    a = np.asarray(dense_logits, np.float32).reshape(-1)
    b = np.asarray(sparse_logits, np.float32).reshape(-1)
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    # random-init logits are near-uniform so argmax is unstable; cosine
    # similarity of the logit vectors is the right closeness measure (block
    # pruning removes whole tiles, so even tiny rates perturb every layer)
    assert cos > 0.85, cos


@pytest.mark.parametrize("net_name", ["alexnet", "googlenet", "resnet50"])
def test_cnn_all_methods_agree(net_name):
    """The paper's contract: sparsity changes speed, never the output."""
    net = cnn.NETWORKS[net_name]()
    rng = np.random.default_rng(0)
    image = 67 if net_name == "alexnet" else 64
    params = cnn.init_cnn(net, 3, rng, image)
    x = jnp.asarray(rng.standard_normal((1, 3, image, image)).astype(np.float32))
    ref = np.asarray(cnn.cnn_forward(net, params, x, "dense"))
    for method in ("lowered", "csr-direct"):
        out = np.asarray(cnn.cnn_forward(net, params, x, method))
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_cnn_pallas_path_agrees():
    net = cnn.NETWORKS["alexnet"]()
    rng = np.random.default_rng(1)
    params = cnn.init_cnn(net, 3, rng, 67)
    x = jnp.asarray(rng.standard_normal((1, 3, 67, 67)).astype(np.float32))
    ref = np.asarray(cnn.cnn_forward(net, params, x, "dense"))
    out = np.asarray(cnn.cnn_forward(net, params, x, "pallas"))
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
