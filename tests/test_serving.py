"""Continuous-batching serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.steps import make_serve_step
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import ContinuousBatcher, Request, ServeEngine

CFG = ModelConfig(name="srv", family="dense", n_layers=2, d_model=64,
                  vocab=128, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                  dtype="float32")


def _engine(n_slots=4, max_len=64):
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    cache = T.init_cache(CFG, n_slots, max_len)
    step = jax.jit(lambda p, t, c, l: (
        lambda out: (out[0], out[1]))(make_serve_step(CFG)(p, t, c, l)))
    return ServeEngine(step, params, cache, n_slots, max_len)


def test_batcher_admit_retire():
    b = ContinuousBatcher(2, 32)
    r1, r2, r3 = (Request(i, [1, 2], max_new_tokens=1) for i in range(3))
    for r in (r1, r2, r3):
        b.submit(r)
    assert b.admit() == 2 and b.active == 2
    assert b.queue == [r3]
    b.slots[0].request.output.append(7)  # hit budget
    retired = b.retire()
    assert retired == [r1] and r1.done
    assert b.admit() == 1 and b.active == 2


def test_batcher_rejects_oversize():
    b = ContinuousBatcher(1, 8)
    r = Request(0, list(range(6)), max_new_tokens=8)
    b.submit(r)
    b.admit()
    assert r.done and b.active == 0


def test_engine_serves_all_requests():
    eng = _engine(n_slots=3, max_len=48)
    reqs = [Request(i, [1 + i, 2, 3], max_new_tokens=4) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert len(r.output) == 4, r
        assert all(0 <= t < CFG.vocab for t in r.output)


def test_engine_deterministic_per_request():
    """The same prompt must yield the same tokens regardless of batch-mates
    ... up to capacity-free attention semantics (dense model: exact)."""
    eng1 = _engine(n_slots=1, max_len=48)
    r_solo = Request(0, [5, 6, 7], max_new_tokens=4)
    eng1.submit(r_solo)
    eng1.run_until_drained()

    eng2 = _engine(n_slots=2, max_len=48)
    r_a = Request(1, [5, 6, 7], max_new_tokens=4)
    r_b = Request(2, [9, 9, 9], max_new_tokens=4)
    eng2.submit(r_a)
    eng2.submit(r_b)
    eng2.run_until_drained()
    assert r_a.output == r_solo.output
