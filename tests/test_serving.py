"""Continuous-batching serving engine tests."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.steps import make_serve_step
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import (ContinuousBatcher, DrainExhaustedWarning, Request,
                           ServeEngine, StragglerTickWarning)

CFG = ModelConfig(name="srv", family="dense", n_layers=2, d_model=64,
                  vocab=128, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                  dtype="float32")


def _engine(n_slots=4, max_len=64):
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    cache = T.init_cache(CFG, n_slots, max_len)
    step = jax.jit(lambda p, t, c, l: (
        lambda out: (out[0], out[1]))(make_serve_step(CFG)(p, t, c, l)))
    return ServeEngine(step, params, cache, n_slots, max_len)


def test_batcher_admit_retire():
    b = ContinuousBatcher(2, 32)
    r1, r2, r3 = (Request(i, [1, 2], max_new_tokens=1) for i in range(3))
    for r in (r1, r2, r3):
        b.submit(r)
    assert b.admit() == 2 and b.active == 2
    assert b.queue == [r3]
    b.slots[0].request.output.append(7)  # hit budget
    retired = b.retire()
    assert retired == [r1] and r1.done
    assert b.admit() == 1 and b.active == 2


def test_batcher_rejects_oversize():
    b = ContinuousBatcher(1, 8)
    r = Request(0, list(range(6)), max_new_tokens=8)
    b.submit(r)
    b.admit()
    assert r.done and b.active == 0
    assert b.rejected == [r]


def test_run_until_drained_surfaces_rejected_request():
    """Regression: oversize rejections are popped from the queue at
    admission, so sweeping only the queue silently dropped them from the
    finished list."""
    eng = _engine(n_slots=1, max_len=8)
    r = Request(0, list(range(6)), max_new_tokens=8)  # 6 + 8 > 8: oversize
    eng.submit(r)
    done = eng.run_until_drained()
    assert r in done and r.done and r.output == []


def test_run_until_drained_mixes_rejected_and_served():
    eng = _engine(n_slots=2, max_len=12)
    ok = Request(0, [1, 2, 3], max_new_tokens=4)
    oversize = Request(1, list(range(10)), max_new_tokens=8)
    eng.submit(ok)
    eng.submit(oversize)
    done = eng.run_until_drained()
    assert ok in done and len(ok.output) == 4
    assert oversize in done and oversize.done and oversize.output == []
    assert len(done) == 2  # no duplicates


def test_staggered_admission_generates_full_budget():
    """Regression: tick() snapped every slot's pos to the global max, so a
    request admitted mid-stream jumped to the deepest slot's depth and
    hit_cap retired it before it generated its full budget.  Now requests
    that don't fit the cache depth remaining above the write cursor wait for
    the batch to drain instead of being truncated."""
    eng = _engine(n_slots=2, max_len=14)
    r1 = Request(0, [1, 2, 3], max_new_tokens=8)
    eng.submit(r1)
    for _ in range(4):
        eng.tick()
    r2 = Request(1, [4, 5, 6], max_new_tokens=8)
    eng.submit(r2)
    eng.run_until_drained()
    assert r1.done and len(r1.output) == 8
    assert r2.done and len(r2.output) == 8


def test_midstream_admission_when_capacity_allows():
    """A request that fits the remaining cache depth is admitted mid-stream
    (true continuous batching) and still generates its full budget; the
    shared write cursor never regresses when the deeper slot retires."""
    eng = _engine(n_slots=2, max_len=32)
    r1 = Request(0, [1, 2, 3], max_new_tokens=10)
    eng.submit(r1)
    for _ in range(4):
        eng.tick()
    r2 = Request(1, [4, 5], max_new_tokens=4)  # 6 <= 32 - 4: fits mid-wave
    eng.submit(r2)
    eng.tick()
    assert eng.batcher.active == 2  # genuinely admitted mid-stream
    eng.run_until_drained()
    assert len(r1.output) == 10 and len(r2.output) == 4


def test_engine_serves_all_requests():
    eng = _engine(n_slots=3, max_len=48)
    reqs = [Request(i, [1 + i, 2, 3], max_new_tokens=4) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert len(r.output) == 4, r
        assert all(0 <= t < CFG.vocab for t in r.output)


def test_engine_deterministic_per_request():
    """The same prompt must yield the same tokens regardless of batch-mates
    ... up to capacity-free attention semantics (dense model: exact)."""
    eng1 = _engine(n_slots=1, max_len=48)
    r_solo = Request(0, [5, 6, 7], max_new_tokens=4)
    eng1.submit(r_solo)
    eng1.run_until_drained()

    eng2 = _engine(n_slots=2, max_len=48)
    r_a = Request(1, [5, 6, 7], max_new_tokens=4)
    r_b = Request(2, [9, 9, 9], max_new_tokens=4)
    eng2.submit(r_a)
    eng2.submit(r_b)
    eng2.run_until_drained()
    assert r_a.output == r_solo.output


def test_serving_telemetry_metrics():
    """A drained run under telemetry records admissions/rejections/
    retirements counters, queue/slot gauges, and a working-tick latency
    histogram with ordered quantiles; disabled runs record nothing."""
    from repro import telemetry

    telemetry.reset()
    eng_off = _engine(n_slots=2, max_len=16)
    eng_off.submit(Request(0, [1, 2], max_new_tokens=2))
    eng_off.run_until_drained()
    assert telemetry.snapshot() == {}  # disabled: zero recording

    with telemetry.enabled():
        eng = _engine(n_slots=2, max_len=16)
        reqs = [Request(i, [1 + i, 2], max_new_tokens=3) for i in range(4)]
        oversize = Request(9, list(range(12)), max_new_tokens=8)
        for r in reqs:
            eng.submit(r)
        eng.submit(oversize)
        eng.run_until_drained()

        snap = telemetry.snapshot()
        assert snap["serving.admissions"]["value"] == 4
        assert snap["serving.rejections"]["value"] == 1
        assert snap["serving.retirements"]["value"] == 4
        assert snap["serving.queue_depth"]["value"] == 0  # drained
        assert snap["serving.active_slots"]["value"] == 0
        hist = telemetry.histogram("serving.tick_latency_s")
        assert hist.count > 0
        assert 0 < hist.min <= hist.p50 <= hist.p95 <= hist.p99 <= hist.max
    telemetry.reset()


def test_straggler_tick_flagged_counted_and_warned_once():
    """A k-sigma outlier tick trips the wired StragglerMonitor: the
    ``serving.straggler_ticks`` counter increments, the EWMA gauge is
    recorded, and exactly one warning names the slow tick."""
    from repro import telemetry

    state = {"n": 0}

    def slow_step(p, t, c, l):
        # Pure-python step: stable microsecond ticks (no jit compile noise
        # in the EWMA), with two deliberate outliers.
        state["n"] += 1
        if state["n"] in (10, 12):  # two stragglers, one warning
            time.sleep(0.05)
        return np.asarray(t)[:, 0] + 1, c

    eng = ServeEngine(slow_step, params=None, cache=None, n_slots=2,
                      max_len=64)
    eng.submit(Request(0, [1, 2, 3], max_new_tokens=16))
    telemetry.reset()
    with telemetry.enabled():
        with pytest.warns(StragglerTickWarning) as caught:
            eng.run_until_drained()
        snap = telemetry.snapshot()
    telemetry.reset()
    assert len(caught) == 1  # warned once, further stragglers only counted
    assert snap["serving.straggler_ticks"]["value"] >= 1
    assert snap["serving.tick_ewma_s"]["value"] > 0
    assert eng.monitor.flags  # the monitor recorded the outlier itself


def test_run_until_drained_reports_exhaustion():
    """Regression: hitting ``max_ticks`` with requests still pending used
    to return a silently incomplete list — now the DrainResult carries the
    drain status, telemetry counts it, and a warning fires."""
    from repro import telemetry

    eng = _engine(n_slots=1, max_len=64)
    reqs = [Request(i, [1, 2, 3], max_new_tokens=8) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    telemetry.reset()
    with telemetry.enabled():
        with pytest.warns(DrainExhaustedWarning):
            out = eng.run_until_drained(max_ticks=2)
        snap = telemetry.snapshot()
    telemetry.reset()
    assert out.drained is False and out.ticks == 2
    assert out.pending == out.pending_queued + out.pending_active > 0
    assert snap["serving.drain_exhausted"]["value"] == 1
    # a completed drain reports clean status on the same engine
    done = eng.run_until_drained()
    assert done.drained is True and done.pending == 0
    assert all(r.done for r in reqs)


def test_sparsify_params_converts_list_and_root_leaves():
    """Regression: ``sparsify_params.visit`` only ran ``conv`` on
    dict-valued parents, so leaves held in lists (and a bare pytree root)
    were silently served dense."""
    from repro.core.sparse_format import BcsrMatrix
    from repro.launch.serve import sparsify_params

    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    w2 = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    tree = {"blocks": [{"proj": w1}, w2], "embed": w2}
    out = sparsify_params(tree, None, 0.5)
    assert isinstance(out["blocks"][0]["proj"], BcsrMatrix)
    assert isinstance(out["blocks"][1], BcsrMatrix)   # list-held leaf
    assert out["embed"] is w2                          # skip-name untouched
    # a list at the pytree root converts too
    out2 = sparsify_params([w1, w2], None, 0.5)
    assert all(isinstance(v, BcsrMatrix) for v in out2)
