"""Decode-vs-prefill consistency: stepping token-by-token through the cache
must reproduce the parallel forward logits.  This cross-validates the KV
cache, absorbed-MLA decode, and the SSD chunked-scan vs single-step
recurrence equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import transformer as T

CAUSAL_ARCHS = [a for a in cfgs.list_archs()
                if cfgs.REGISTRY[a].FAMILY not in ("encoder",)]


@pytest.mark.parametrize("arch", CAUSAL_ARCHS)
def test_decode_matches_forward(arch):
    import dataclasses
    from repro.models import flags as F
    # f32: tests algorithmic consistency; bf16 noise near router ties would
    # otherwise flip top-k expert choices and amplify discontinuously.
    # High capacity factor: capacity drops are legitimate batch-dependent
    # semantics (verified separately); here we test the algorithm.
    cfg = dataclasses.replace(cfgs.get_config(arch, smoke=True),
                              dtype="float32")
    F.set_moe_capacity(8.0)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    b, t = 2, 16  # multiple of smoke ssm_chunk=8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab,
                              jnp.int32)
    ref_logits, _ = T.forward(params, toks, cfg)
    ref = np.asarray(ref_logits, np.float32)

    cache = T.init_cache(cfg, b, t)
    step = jax.jit(lambda p, tok, c, l: T.decode_step(p, cfg, tok, c, l))
    got = []
    for i in range(t):
        lg, cache = step(params, toks[:, i:i + 1], cache, jnp.int32(i))
        got.append(np.asarray(lg, np.float32))
    got = np.stack(got, axis=1)
    if cfg.n_experts:
        # Capacity-based MoE may legitimately route a token differently when
        # batched (capacity drops) — require almost-all elements to match.
        close = np.isclose(got, ref, rtol=1e-2, atol=1e-2).mean()
        assert close >= 0.99, close
    else:
        np.testing.assert_allclose(got, ref, rtol=1e-2, atol=1e-2)
    # top-1 prediction must agree at (almost) every position
    agree = (got.argmax(-1) == ref.argmax(-1)).mean()
    assert agree >= 0.95, agree
