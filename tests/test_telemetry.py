"""Telemetry subsystem tests: metrics registry, Chrome-trace exporter,
one-time fallback warnings, plan-cache provenance counters, and the
engine's per-forward ExecutionReport (all three paper networks)."""
import json
import warnings

import numpy as np
import pytest

from repro import telemetry
from repro.engine import CnnEngine, lower
from repro.models import cnn
from repro.tuning import PlanCache, apply_plan_to_params, plan_program
from repro.tuning.measure import TimingStats, time_fn

SMOKE = [("alexnet", 67), ("googlenet", 48), ("resnet50", 48)]


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Telemetry is process-global: every test starts and ends clean."""
    telemetry.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


def _micro_net():
    return [
        cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
        cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu(),
        cnn.Pool("gap"), cnn.FC("fc", 10),
    ]


def _micro_engine(image=8):
    rng = np.random.default_rng(0)
    net = _micro_net()
    program = lower(net, (3, image, image))
    params = cnn.init_cnn(net, 3, rng, image)
    plan = plan_program(program, batch=1, mode="roofline", cache=PlanCache())
    apply_plan_to_params(params, plan)
    x = rng.standard_normal((1, 3, image, image)).astype(np.float32)
    return CnnEngine(program, params, plan), x


# ---------------------------------------------------------------- metrics

def test_counter_and_gauge():
    c = telemetry.counter("t.c")
    c.inc()
    c.inc(3)
    telemetry.gauge("t.g").set(7)
    snap = telemetry.snapshot()
    assert snap["t.c"] == {"type": "counter", "value": 4}
    assert snap["t.g"]["value"] == 7.0


def test_histogram_quantiles():
    h = telemetry.histogram("t.h")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100 and h.min == 1.0 and h.max == 100.0
    assert h.p50 == 50.0 and h.p95 == 95.0 and h.p99 == 99.0
    assert h.p50 <= h.p95 <= h.p99
    d = h.to_dict()
    assert d["mean"] == pytest.approx(50.5)
    # empty histogram quantiles are 0, not NaN/inf
    assert telemetry.histogram("t.empty").p99 == 0.0


def test_registry_type_mismatch_raises():
    telemetry.counter("t.typed")
    with pytest.raises(TypeError):
        telemetry.gauge("t.typed")


def test_reset_clears_registry():
    telemetry.counter("t.c").inc()
    telemetry.reset()
    assert telemetry.snapshot() == {}


# ----------------------------------------------------------------- tracer

def test_tracer_exports_valid_chrome_trace(tmp_path):
    tracer = telemetry.Tracer()
    with tracer.span("outer", cat="test", foo=1):
        tracer.instant("marker", cat="test")
    tracer.complete("op", start_s=None, dur_s=1e-3, cat="op.roofline",
                    tid=telemetry.TID_ROOFLINE, args={"method": "pallas"})
    doc = tracer.to_chrome_trace()
    telemetry.validate_chrome_trace(doc)  # must not raise
    assert doc["displayTimeUnit"] == "ms"
    phases = [ev["ph"] for ev in doc["traceEvents"]]
    assert "M" in phases and "X" in phases and "i" in phases
    path = tmp_path / "trace.json"
    tracer.export(str(path))
    telemetry.validate_chrome_trace(json.loads(path.read_text()))


def test_validate_chrome_trace_rejects_bad_docs():
    with pytest.raises(ValueError):
        telemetry.validate_chrome_trace({"no": "traceEvents"})
    with pytest.raises(ValueError):  # X event needs a non-negative dur
        telemetry.validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 0,
             "dur": -5}]})
    with pytest.raises(ValueError):  # args must be JSON-serializable
        telemetry.validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 0,
             "args": {"bad": object()}}]})


# --------------------------------------------------- fallback warnings

def test_fallback_warns_once_per_layer_and_reason():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        telemetry.record_fallback("sparse_conv", "no_feasible_tiling",
                                  layer="conv2", geometry="m=4 c=4",
                                  fallback_to="csr-direct")
        telemetry.record_fallback("sparse_conv", "no_feasible_tiling",
                                  layer="conv2", geometry="m=4 c=4",
                                  fallback_to="csr-direct")
    hits = [x for x in w if issubclass(x.category,
                                       telemetry.SparseFallbackWarning)]
    assert len(hits) == 1  # once per (kernel, layer, reason), not per call
    msg = str(hits[0].message)
    assert "no_feasible_tiling" in msg and "conv2" in msg and "m=4" in msg

    # a different layer (and a different reason) each warn again
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        telemetry.record_fallback("sparse_conv", "no_feasible_tiling",
                                  layer="conv3")
        telemetry.record_fallback("sparse_conv", "smem_infeasible",
                                  layer="conv2")
    assert len(w) == 2


def test_fallback_warning_is_independent_of_telemetry_state():
    """The one-time warning fires with telemetry disabled (always-on);
    the counters only move when telemetry is enabled."""
    assert not telemetry.is_enabled()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        telemetry.record_fallback("bsr_conv", "smem_infeasible",
                                  layer="conv9", fallback_to="dense")
    assert len(w) == 1
    assert "fallback.total" not in telemetry.snapshot()

    telemetry.reset_warnings()
    with telemetry.enabled(), warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        telemetry.record_fallback("bsr_conv", "smem_infeasible",
                                  layer="conv9", fallback_to="dense")
    snap = telemetry.snapshot()
    assert snap["fallback.total"]["value"] == 1
    assert snap["fallback.bsr_conv.smem_infeasible"]["value"] == 1


def test_fallback_unknown_reason_raises():
    with pytest.raises(ValueError):
        telemetry.record_fallback("sparse_conv", "not_a_reason")


# ------------------------------------------------------------ TimingStats

def test_time_fn_returns_spread():
    t = time_fn(lambda: sum(range(200)), warmup=1, iters=5)
    assert isinstance(t, TimingStats) and isinstance(t, float)
    assert t.min <= t.p50 <= t.max
    assert t.p50 == float(t)
    assert t * 1e3 == pytest.approx(float(t) * 1e3)  # arithmetic still works
    assert t.spread == pytest.approx(t.max - t.min)


# ------------------------------------- plan-cache provenance counters

def test_plan_cache_migration_counters(tmp_path):
    """Loading every migratable schema (v1-v5) under telemetry counts each
    entry as a migration and marks its provenance; a current-version reload
    counts as cache hits instead."""
    from repro.tuning.cache import MIGRATABLE_VERSIONS

    fixtures = {
        1: {"method": "pallas", "tm": 64, "pad_to": 8},
        2: {"method": "pallas", "tm": 32, "te": 16, "tf": 16, "pad_to": 8},
        3: {"method": "pallas", "tm": 16, "te": 16, "tf": 16, "pad_to": 8,
            "fuse": True},
        4: {"method": "pallas", "tm": 16, "te": 16, "tf": 16, "pad_to": 8,
            "fuse": True, "pipeline": True, "permute": True},
        5: {"method": "bsr", "te": 16, "tf": 16, "fuse": True,
            "block_m": 8, "block_n": 128},
    }
    assert set(fixtures) == set(MIGRATABLE_VERSIONS)
    with telemetry.enabled():
        for ver, entry in fixtures.items():
            p = tmp_path / f"v{ver}.json"
            p.write_text(json.dumps(
                {"version": ver, "entries": {"k": entry}}))
            cache = PlanCache(str(p))
            assert cache.get("k").provenance == "migrated"
        snap = telemetry.snapshot()
        assert snap["tuning.cache.loads"]["value"] == len(fixtures)
        assert snap["tuning.cache.load_migrations"]["value"] == len(fixtures)
        # re-persist one and reload: current version -> cache_hit, and the
        # migration counter does not move
        out = tmp_path / "v6.json"
        cache.save(str(out))
        assert PlanCache(str(out)).get("k").provenance == "cache_hit"
        snap = telemetry.snapshot()
        assert snap["tuning.cache.load_migrations"]["value"] == len(fixtures)
        assert snap["tuning.cache.loads"]["value"] == len(fixtures) + 1


def test_plan_provenance_fresh_then_cache_hit(tmp_path):
    """A fresh tune marks entries freshly_tuned (dense-kept layers:
    default); re-planning from the persisted cache marks them cache_hit and
    bumps the hit counter."""
    net = _micro_net()
    program = lower(net, (3, 8, 8))
    path = tmp_path / "cache.json"
    cache = PlanCache(str(path))
    plan = plan_program(program, batch=1, mode="roofline", cache=cache)
    assert all(pe.provenance in ("freshly_tuned", "default")
               for pe in plan.values())
    assert any(pe.provenance == "freshly_tuned" for pe in plan.values())

    with telemetry.enabled():
        replan = plan_program(program, batch=1, mode="roofline",
                              cache=PlanCache(str(path)))
        assert replan == plan  # provenance is excluded from equality
        assert all(pe.provenance == "cache_hit" for pe in replan.values())
        assert (telemetry.snapshot()["tuning.plan.cache_hit"]["value"]
                == len(replan))


# -------------------------------------------------- ExecutionReport

@pytest.mark.parametrize("net_name,image", SMOKE)
def test_execution_report_all_networks(net_name, image):
    """Under a healthy tuned plan, every conv layer's report pins the
    planned method with zero silent fallbacks — built without executing."""
    rng = np.random.default_rng(0)
    net = cnn.NETWORKS[net_name]()
    program = lower(net, (3, image, image))
    params = cnn.init_cnn(net, 3, rng, image)
    plan = plan_program(program, batch=1, mode="roofline", cache=PlanCache())
    apply_plan_to_params(params, plan)
    engine = CnnEngine(program, params, plan)

    report = engine.execution_report((1, 3, image, image), "auto")
    n_convs = len(program.conv_table)
    assert len(report.ops) == n_convs and n_convs > 0
    assert report.fallback_count == 0, report.format()
    for op in report.ops:
        assert op.method_executed == op.method_planned
        assert op.fallback_reason is None
        assert op.provenance in ("freshly_tuned", "default")
        assert op.flops > 0 and op.hbm_bytes > 0 and op.est_s > 0
    # the report names real executed methods, and the sparse layers left
    # the dense path
    assert set(report.methods_executed) <= {
        "dense", "lowered", "csr-direct", "pallas", "bsr"}
    sparse_ops = [o for o in report.ops if o.sparsity > 0]
    assert sparse_ops and all(o.method_executed != "dense"
                              for o in sparse_ops)
    # the rendered table carries one row per conv
    assert report.format().count("\n") >= n_convs
    # per-op roofline spans export as a valid Chrome trace
    tracer = telemetry.Tracer()
    report.emit_spans(tracer)
    doc = tracer.to_chrome_trace()
    telemetry.validate_chrome_trace(doc)
    span_names = {ev["name"] for ev in doc["traceEvents"]
                  if ev["ph"] == "X"}
    assert {op.name for op in report.ops} <= span_names


def test_forward_records_report_and_valid_trace(tmp_path):
    engine, x = _micro_engine()
    y_off = np.asarray(engine(x, "auto"))  # telemetry disabled
    assert engine.last_report is None
    assert telemetry.snapshot() == {} and len(telemetry.get_tracer()) == 0

    with telemetry.enabled():
        y_on = np.asarray(engine(x, "auto"))
    np.testing.assert_array_equal(y_off, y_on)  # bit-identical either way

    report = engine.last_report
    assert report is not None and not report.timed
    assert report.fallback_count == 0
    assert report.jit_cache_hit  # second forward reuses the compiled fn
    snap = telemetry.snapshot()
    assert snap["engine.forwards"]["value"] == 1
    assert snap["engine.jit_hits"]["value"] == 1
    # roofline-attributed spans landed on the tracer and export validates
    assert len(telemetry.get_tracer()) >= len(report.ops)
    path = tmp_path / "trace.json"
    telemetry.get_tracer().export(str(path))
    doc = json.loads(path.read_text())
    telemetry.validate_chrome_trace(doc)
    names = {ev["name"] for ev in doc["traceEvents"] if ev["ph"] == "X"}
    assert {op.name for op in report.ops} <= names


def test_forward_timed_fills_wall_times():
    engine, x = _micro_engine()
    y = np.asarray(engine.forward_timed(x, "auto"))
    y_ref = np.asarray(engine(x, "auto"))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-6)
    report = engine.last_report
    assert report is not None and report.timed
    for op in report.ops:
        assert op.wall_s is not None and op.wall_s >= 0.0
    # timed mode records wall spans regardless of the global flag — calling
    # it is the opt-in
    assert len(telemetry.get_tracer()) > 0


def test_stale_bsr_plan_reports_fallback():
    """A stale bsr plan entry (no block shape) must surface as a
    machine-readable stale_plan_no_block fallback in the report."""
    import dataclasses

    engine, x = _micro_engine()
    stale = {k: pe for k, pe in engine.plan.items()}
    sparse_key = next(k for k, pe in stale.items()
                      if pe.method not in ("dense",))
    stale[sparse_key] = dataclasses.replace(
        stale[sparse_key], method="bsr", block_m=None, block_n=None)
    engine2 = CnnEngine(engine.program, engine.params, stale)
    report = engine2.execution_report(tuple(x.shape), "auto")
    bad = [o for o in report.ops if o.fallback_reason is not None]
    assert len(bad) == 1
    assert bad[0].fallback_reason == "stale_plan_no_block"
    assert bad[0].method_executed == "dense"
    assert report.fallback_count == 1
