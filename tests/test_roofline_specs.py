"""Roofline HLO parsing + input-spec construction (no device allocation)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as cfgs
from repro.launch import roofline as rl
from repro.launch import specs as S


HLO_SAMPLE = """
  %ag = bf16[16,2048]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[8,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = (f32[4,32]{1,0}, f32[4,32]{1,0}) all-to-all(%a, %b)
  %cp = bf16[256]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ard = f32[64]{0} all-reduce-done(%h)
  %dot = f32[128,128]{1,0} dot(%l, %r)
"""


def test_collective_bytes_parsing():
    out = rl.collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 16 * 2048 * 2
    assert out["all-reduce"] == 1024 * 4 + 64 * 4  # includes -done variant
    assert out["reduce-scatter"] == 8 * 128 * 4
    assert out["all-to-all"] == 2 * 4 * 32 * 4     # tuple result
    assert out["collective-permute"] == 256 * 2


def test_collective_bytes_ignores_compute():
    out = rl.collective_bytes("%dot = f32[512,512]{1,0} dot(%a, %b)")
    assert sum(out.values()) == 0


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(arch="a", shape="s", mesh="m", flops=197e12,
                    hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
                    coll_breakdown={}, model_flops=98.5e12)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.useful_ratio - 0.5) < 1e-9
    # useful flops at the 2s bound vs peak
    assert abs(r.roofline_fraction - (98.5e12 / 2.0) / 197e12) < 1e-9


def test_model_flops_global():
    cfg = cfgs.get_config("yi-9b")
    tr = rl.model_flops_global(cfg, cfgs.SHAPE_BY_NAME["train_4k"])
    pf = rl.model_flops_global(cfg, cfgs.SHAPE_BY_NAME["prefill_32k"])
    dc = rl.model_flops_global(cfg, cfgs.SHAPE_BY_NAME["decode_32k"])
    n = cfg.active_params()
    assert abs(tr - 6 * n * 4096 * 256) / tr < 1e-9
    assert abs(pf - 2 * n * 32768 * 32) / pf < 1e-9
    assert abs(dc - 2 * n * 128) / dc < 1e-9


@pytest.mark.parametrize("arch", ["yi-9b", "hubert-xlarge", "mamba2-2.7b"])
@pytest.mark.parametrize("shape_name",
                         ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_shapes(arch, shape_name):
    cfg = cfgs.get_config(arch)
    shapes = {s.name for s in cfgs.applicable_shapes(arch)}
    if shape_name not in shapes:
        pytest.skip("cell skipped by design")
    shape = cfgs.SHAPE_BY_NAME[shape_name]
    sds, parts = S.input_specs(cfg, shape, tp=16, dp=16)
    if shape.kind == "train":
        key = "embeds" if cfg.family in ("vlm", "encoder") else "tokens"
        assert sds[key].shape[:2] == (shape.global_batch, shape.seq_len)
        assert sds["labels"].shape == (shape.global_batch, shape.seq_len)
    elif shape.kind == "decode":
        assert sds["tokens"].shape == (shape.global_batch, 1)
        # cache leaves exist and carry seq_len where applicable
        leaves = jax.tree.leaves(sds["cache"])
        assert leaves, "decode cell must have a cache"
    # every spec tree leaf must be a PartitionSpec
    for leaf in jax.tree.leaves(parts, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(leaf, P)


def test_long_500k_batch1_drops_dp():
    cfg = cfgs.get_config("mamba2-2.7b")
    shape = cfgs.SHAPE_BY_NAME["long_500k"]
    _, parts = S.input_specs(cfg, shape, tp=16, dp=16)
    for leaf in jax.tree.leaves(parts, is_leaf=lambda x: isinstance(x, P)):
        assert "dp" not in tuple(leaf), leaf


def test_skip_table_matches_design():
    skips = dict()
    for arch in cfgs.list_archs():
        skips[arch] = {n for n, _ in cfgs.skipped_shapes(arch)}
    assert skips["jamba-1.5-large-398b"] == set()
    assert skips["mamba2-2.7b"] == set()
    assert skips["hubert-xlarge"] == {"decode_32k", "long_500k"}
    for dense_arch in ("yi-9b", "qwen1.5-0.5b", "mistral-large-123b",
                      "deepseek-v3-671b", "phi-3-vision-4.2b"):
        assert skips[dense_arch] == {"long_500k"}
    total_cells = sum(len(cfgs.applicable_shapes(a)) for a in cfgs.list_archs())
    assert total_cells == 31
