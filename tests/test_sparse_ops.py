"""Direct sparse conv + sparse linear vs dense oracles (pure-JAX layer)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bcsr_from_dense, bcsr_matmul, block_prune, dense_conv,
                        dense_matmul, direct_sparse_conv, ell_from_dense,
                        ell_from_dense_conv, ell_matmul, im2col,
                        lowered_dense_conv, lowered_sparse_conv,
                        magnitude_prune)


def _conv_case(rng, n, c, h, w, m, r, sparsity, dtype=np.float32):
    x = rng.standard_normal((n, c, h, w)).astype(dtype)
    wt = rng.standard_normal((m, c, r, r)).astype(np.float32)
    wt = np.asarray(magnitude_prune(jnp.asarray(wt), sparsity)).astype(dtype)
    return jnp.asarray(x), wt


CONV_CASES = [
    # (N, C, H, W, M, R, stride, pad, sparsity)
    (2, 3, 12, 12, 8, 3, 1, 0, 0.7),
    (1, 8, 9, 9, 16, 3, 1, 1, 0.9),
    (2, 4, 16, 16, 8, 5, 1, 2, 0.8),
    (2, 4, 17, 17, 8, 3, 2, 1, 0.8),   # stride 2, odd size
    (1, 2, 23, 23, 4, 11, 4, 0, 0.6),  # alexnet-conv1-like
    (3, 16, 8, 8, 32, 1, 1, 0, 0.85),  # 1x1 conv
]


@pytest.mark.parametrize("case", CONV_CASES)
def test_direct_sparse_conv_matches_dense(case):
    n, c, h, w, m, r, stride, pad, sp = case
    rng = np.random.default_rng(hash(case) % 2**31)
    x, wt = _conv_case(rng, n, c, h, w, m, r, sp)
    ref = dense_conv(x, jnp.asarray(wt), stride=stride, padding=pad)
    got = direct_sparse_conv(x, ell_from_dense_conv(wt), stride=stride,
                             padding=pad)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CONV_CASES[:4])
def test_lowering_baselines_match_dense(case):
    n, c, h, w, m, r, stride, pad, sp = case
    rng = np.random.default_rng(hash(case) % 2**31)
    x, wt = _conv_case(rng, n, c, h, w, m, r, sp)
    ref = dense_conv(x, jnp.asarray(wt), stride=stride, padding=pad)
    low = lowered_dense_conv(x, jnp.asarray(wt), stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(low), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    ell2d = ell_from_dense(wt.reshape(m, -1))
    lsp = lowered_sparse_conv(x, ell2d, r, r, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(lsp), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_im2col_duplication_factor():
    """The lowering method's bandwidth waste the paper fixes: the lowered
    matrix holds ~R*S copies of each input element."""
    x = jnp.ones((1, 2, 8, 8))
    cols = im2col(x, 3, 3, padding=1)
    assert cols.size == 8 * 8 * 2 * 9  # E*F x C*R*S duplication


def test_direct_conv_bf16():
    rng = np.random.default_rng(0)
    x, wt = _conv_case(rng, 2, 4, 10, 10, 8, 3, 0.8)
    xb = x.astype(jnp.bfloat16)
    ref = dense_conv(xb, jnp.asarray(wt).astype(jnp.bfloat16), padding=1)
    got = direct_sparse_conv(xb, ell_from_dense_conv(wt.astype(jnp.bfloat16)),
                             padding=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("mn", [(16, 32), (128, 96), (200, 200), (8, 8)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.95])
def test_ell_matmul(mn, sparsity):
    m, n = mn
    rng = np.random.default_rng(m * n)
    w = rng.standard_normal((m, n)).astype(np.float32)
    w = np.asarray(magnitude_prune(jnp.asarray(w), sparsity))
    x = jnp.asarray(rng.standard_normal((3, 5, n)).astype(np.float32))
    ref = dense_matmul(x, jnp.asarray(w))
    got = ell_matmul(x, ell_from_dense(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("block", [(8, 8), (16, 32), (32, 16)])
@pytest.mark.parametrize("sparsity", [0.3, 0.8])
def test_bcsr_matmul(block, sparsity):
    rng = np.random.default_rng(block[0] * 100 + block[1])
    w = rng.standard_normal((96, 160)).astype(np.float32)
    w = np.asarray(block_prune(jnp.asarray(w), sparsity, block))
    x = jnp.asarray(rng.standard_normal((7, 160)).astype(np.float32))
    ref = dense_matmul(x, jnp.asarray(w))
    got = bcsr_matmul(x, bcsr_from_dense(w, block))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_all_zero_weight():
    """Fully pruned filter bank: output must be exactly zero (padding rows
    are inert)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 2, 6, 6)),
                    dtype=jnp.float32)
    wt = np.zeros((4, 2, 3, 3), np.float32)
    out = direct_sparse_conv(x, ell_from_dense_conv(wt))
    np.testing.assert_array_equal(np.asarray(out), 0.0)
