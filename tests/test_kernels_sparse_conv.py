"""Pallas direct-sparse-conv kernel: interpret-mode sweeps vs the jnp oracle,
including the fused epilogue (bias / ReLU / residual in-kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import balance_ell_conv, ell_from_dense_conv, magnitude_prune
from repro.core.direct_conv import out_spatial
from repro.kernels.sparse_conv import ops
from repro.kernels.sparse_conv.kernel import sparse_conv_pallas
from repro.kernels.sparse_conv.ops import (choose_tiles, choose_tm,
                                           smem_fits, sparse_conv,
                                           tile_candidates, tm_candidates)
from repro.kernels.sparse_conv.ref import sparse_conv_ref

pytestmark = pytest.mark.pallas

CASES = [
    # (N, C, H, W, M, R, pad, sparsity)
    (1, 3, 10, 10, 8, 3, 0, 0.7),
    (2, 8, 12, 12, 16, 3, 1, 0.9),
    (1, 4, 9, 9, 8, 5, 2, 0.8),
    (2, 16, 8, 8, 32, 1, 0, 0.85),   # 1x1
    (1, 2, 7, 11, 4, 3, 1, 0.5),     # non-square input
    (1, 6, 14, 14, 12, 3, 1, 0.0),   # fully dense weights via sparse path
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_oracle(case):
    n, c, h, w, m, r, pad, sp = case
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)).astype(np.float32))
    wt = rng.standard_normal((m, c, r, r)).astype(np.float32)
    if sp > 0:
        wt = np.asarray(magnitude_prune(jnp.asarray(wt), sp))
    ell = ell_from_dense_conv(wt)
    got = sparse_conv(x, ell, padding=pad, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=pad)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 4, 10, 10)), dtype=dtype)
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.8))
    ell = ell_from_dense_conv(wt.astype(np.float32))
    import dataclasses
    ell = dataclasses.replace(ell, value=ell.value.astype(dtype))
    got = sparse_conv(x, ell, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("tm", [1, 2, 4, 8])
def test_kernel_channel_tiles(tm):
    """Every channel-tile size produces identical results."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 4, 8, 8)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    got = sparse_conv(x, ell, tm=tm, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_strided_runs_in_kernel(monkeypatch):
    """stride > 1 now runs through the Pallas kernel (no pure-JAX fallback)."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((1, 3, 16, 16)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 3, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    launches = []
    real = ops.sparse_conv_pallas
    monkeypatch.setattr(
        ops, "sparse_conv_pallas",
        lambda *a, **kw: launches.append(kw) or real(*a, **kw))
    got = sparse_conv(x, ell, stride=2, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), stride=2, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert launches and launches[0]["stride"] == 2


# ---------------------------------------------------------------------------
# spatial tiling: stride x padding grid, edge tiles, large feature maps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("pad", [0, 1, 2])
def test_strided_tiled_parity(stride, pad):
    """(stride, padding) grid through the spatially-tiled kernel with edge
    tiles: te/tf deliberately do not divide E/F."""
    n, c, h, w, m, r = 2, 3, 15, 13, 8, 3
    rng = np.random.default_rng(100 * stride + pad)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    e, f = out_spatial(h, w, r, r, stride, pad)
    te, tf = max(1, (e + 1) // 2), max(1, f // 2 + 1)   # non-dividing tiles
    got = sparse_conv(x, ell, stride=stride, padding=pad,
                      tm=4, te=te, tf=tf, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride", [2, 4])
def test_strided_bf16(stride):
    rng = np.random.default_rng(23 + stride)
    x = jnp.asarray(rng.standard_normal((1, 4, 12, 12)), dtype=jnp.bfloat16)
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.8))
    ell = ell_from_dense_conv(wt)
    import dataclasses
    ell = dataclasses.replace(ell, value=ell.value.astype(jnp.bfloat16))
    got = sparse_conv(x, ell, stride=stride, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), stride=stride, padding=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_large_feature_map_spatially_tiled():
    """A feature map whose whole padded image busts the VMEM budget still
    runs through the Pallas kernel via spatial tiling — the old kernel
    refused it (and the [1]-fallback bug would have launched over budget)."""
    n, c, h, w, m, r, pad = 1, 96, 192, 192, 8, 3, 1
    hp = wp = h + 2 * pad
    assert c * hp * wp * 4 > ops.VMEM_BUDGET  # genuinely oversized
    rng = np.random.default_rng(31)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)), 0.95))
    ell = ell_from_dense_conv(wt)
    e, f = out_spatial(h, w, r, r, 1, pad)
    # regression: the untiled ladder must report infeasible, not [1]
    assert tm_candidates(m, c, hp, wp, e, f, ell.k) == []
    tiles = choose_tiles(m, c, e, f, ell.k, r, r, 1)
    assert tiles is not None and (tiles[1] < e or tiles[2] < f)
    got = sparse_conv(x, ell, padding=pad, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=pad)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_tm_candidates_over_budget_returns_empty():
    """Regression: tm_candidates used to return [1] even when TM=1 busts
    the VMEM budget, launching an over-budget kernel."""
    assert tm_candidates(m=8, c=2048, hp=64, wp=64, e=62, f=62, k=64) == []


def test_off_ladder_tm_honored(monkeypatch):
    """A pinned tm that divides M but is not on the default ladder (e.g. 24
    for M=48) must still launch the kernel, not silently fall back."""
    rng = np.random.default_rng(41)
    x = jnp.asarray(rng.standard_normal((1, 3, 8, 8)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((48, 3, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    launches = []
    real = ops.sparse_conv_pallas
    monkeypatch.setattr(
        ops, "sparse_conv_pallas",
        lambda *a, **kw: launches.append(kw) or real(*a, **kw))
    got = sparse_conv(x, ell, tm=24, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert launches and launches[0]["tm"] == 24


def test_vmem_infeasible_falls_back_to_direct(monkeypatch):
    """When no (tm, te, tf) tiling fits VMEM, sparse_conv must fall back to
    the pure-JAX direct path instead of launching the kernel."""
    rng = np.random.default_rng(37)
    x = jnp.asarray(rng.standard_normal((1, 4, 10, 10)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    monkeypatch.setattr(ops, "_VMEM_BUDGET", 1024)
    assert tile_candidates(8, 4, 8, 8, ell.k, 3, 3, 1) == []

    def _boom(*a, **kw):
        raise AssertionError("over-budget kernel launch")

    monkeypatch.setattr(ops, "sparse_conv_pallas", _boom)
    got = sparse_conv(x, ell, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# double-buffered halo DMA pipeline: parity vs the blocking schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("residual", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pipelined_matches_blocking(stride, residual, dtype):
    """Interpret-mode parity grid: the double-buffered schedule must be
    *bit-identical* to the single-buffer one (same FMA order, different
    staging only) across stride x residual x dtype, with edge tiles (te/tf
    deliberately not dividing E/F) so the prefetch crosses ragged cells."""
    import dataclasses
    n, c, h, w, m, r, pad = 2, 4, 13, 11, 8, 3, 1
    rng = np.random.default_rng(9000 + 100 * stride + 10 * residual
                                + (dtype == jnp.bfloat16))
    x = jnp.asarray(rng.standard_normal((n, c, h, w)), dtype=dtype)
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    if dtype == jnp.bfloat16:
        ell = dataclasses.replace(ell, value=ell.value.astype(dtype))
    bias = jnp.asarray(rng.standard_normal((m,)).astype(np.float32))
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = (jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32),
                       dtype=dtype) if residual else None)
    te, tf = max(1, (e + 1) // 2), max(1, f // 2 + 1)   # non-dividing tiles
    kw = dict(stride=stride, padding=pad, tm=4, te=te, tf=tf, bias=bias,
              fuse_relu=True, residual=res, interpret=True)
    y_block = sparse_conv(x, ell, pipeline=False, **kw)
    y_pipe = sparse_conv(x, ell, pipeline=True, **kw)
    np.testing.assert_array_equal(np.asarray(y_block, np.float32),
                                  np.asarray(y_pipe, np.float32))
    ref = sparse_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    ref = ref.astype(jnp.float32) + bias[None, :, None, None]
    if res is not None:
        ref = ref + res.astype(jnp.float32)
    ref = jax.nn.relu(ref)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y_pipe, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_pipeline_auto_enabled_when_it_fits(monkeypatch):
    """pipeline=None (default) must launch the double-buffered schedule
    whenever the second halo buffer fits the VMEM budget."""
    rng = np.random.default_rng(43)
    x = jnp.asarray(rng.standard_normal((1, 4, 10, 10)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    launches = []
    real = ops.sparse_conv_pallas
    monkeypatch.setattr(
        ops, "sparse_conv_pallas",
        lambda *a, **kw: launches.append(kw) or real(*a, **kw))
    got = sparse_conv(x, ell, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert launches and launches[0]["pipeline"] is True


def test_pipeline_drops_to_single_buffer_when_double_halo_busts(monkeypatch):
    """A requested pipeline=True whose second halo block busts VMEM must
    run the single-buffer blocking kernel — not the pure-JAX fallback."""
    rng = np.random.default_rng(47)
    x = jnp.asarray(rng.standard_normal((1, 4, 16, 16)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    e = f = 16
    tm, te, tf = 8, 16, 16
    # Budget: exactly one halo block + values + out tile — no second buffer.
    x_bytes = 4 * 18 * 18 * 4
    budget = x_bytes + tm * ell.k * 4 + tm * te * tf * 4
    monkeypatch.setattr(ops, "_VMEM_BUDGET", budget)
    assert ops.tiling_fits(8, 4, e, f, ell.k, 3, 3, 1, tm, te, tf)
    assert not ops.tiling_fits(8, 4, e, f, ell.k, 3, 3, 1, tm, te, tf,
                               pipeline=True)
    launches = []
    real = ops.sparse_conv_pallas
    monkeypatch.setattr(
        ops, "sparse_conv_pallas",
        lambda *a, **kw: launches.append(kw) or real(*a, **kw))
    got = sparse_conv(x, ell, padding=1, tm=tm, te=te, tf=tf, pipeline=True,
                      interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert launches and launches[0]["pipeline"] is False


# ---------------------------------------------------------------------------
# nnz-balanced channel packing: permuted bank is invisible to callers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
def test_balanced_bank_output_bit_identical(stride):
    """A permuted (nnz-balanced) ELL bank must produce *bit-identical*
    output to the natural-order bank: row contents (and therefore each
    row's f32 accumulation order) are untouched, only row order changes and
    the inverse permutation restores it."""
    n, c, h, w, m, r, pad = 2, 4, 12, 12, 16, 3, 1
    rng = np.random.default_rng(6000 + stride)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    bal = balance_ell_conv(ell)
    # the permutation actually balances: nnz descending
    nnz = np.asarray(bal.nnz)
    assert (np.diff(nnz) <= 0).all()
    assert sorted(np.asarray(bal.perm).tolist()) == list(range(m))
    bias = jnp.asarray(rng.standard_normal((m,)).astype(np.float32))
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32))
    kw = dict(stride=stride, padding=pad, bias=bias, fuse_relu=True,
              residual=res, interpret=True)
    y_nat = sparse_conv(x, ell, **kw)
    y_bal = sparse_conv(x, bal, **kw)
    np.testing.assert_array_equal(np.asarray(y_nat), np.asarray(y_bal))


def test_balanced_bank_fallback_unpermutes(monkeypatch):
    """The pure-JAX fallback must also restore natural channel order for a
    permuted bank (and apply the epilogue on the restored order)."""
    rng = np.random.default_rng(61)
    x = jnp.asarray(rng.standard_normal((1, 4, 10, 10)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    bal = balance_ell_conv(ell_from_dense_conv(wt))
    bias = jnp.asarray(rng.standard_normal((8,)).astype(np.float32))
    monkeypatch.setattr(ops, "_VMEM_BUDGET", 1024)

    def _boom(*a, **kw):
        raise AssertionError("over-budget kernel launch")

    monkeypatch.setattr(ops, "sparse_conv_pallas", _boom)
    got = sparse_conv(x, bal, padding=1, bias=bias, fuse_relu=True,
                      interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    ref = jax.nn.relu(ref.astype(jnp.float32) + bias[None, :, None, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# regressions: SMEM accounting, non-dividing channel tiles
# ---------------------------------------------------------------------------

def test_smem_fits_budgets_nnz_row():
    """Regression: smem_fits must account all *three* scalar-prefetched
    operands — packed indices, the int32 nnz row, and the f32 bias row.
    Pick (m, k) where indices + bias alone fit but adding the nnz row
    overshoots: the old two-term check said yes and overshot SMEM."""
    budget = ops.SMEM_BUDGET
    m = 1024
    # m*k*4 + m*4 <= budget < m*k*4 + 2*m*4
    k = (budget - m * 4) // (m * 4)
    assert m * k * 4 + m * 4 <= budget < m * k * 4 + 2 * m * 4
    assert not smem_fits(m, k)
    assert smem_fits(m, k - 1)


def test_non_dividing_tm_raises_value_error():
    """The kernel wrapper must reject a non-dividing channel tile with a
    ValueError naming the geometry — an assert would vanish under
    ``python -O`` and silently mis-tile."""
    rng = np.random.default_rng(53)
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 3, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    xpad = jnp.asarray(rng.standard_normal((1, 3, 10, 10)).astype(np.float32))
    with pytest.raises(ValueError, match=r"tm=3 does not divide M=8"):
        sparse_conv_pallas(
            xpad, ell.value, ops.pack_indices(ell), ell.nnz,
            jnp.zeros((8,), jnp.float32), tm=3, k=ell.k, rs=9, s=3,
            e=8, f=8, interpret=True)


def test_stale_plan_non_dividing_tm_falls_back(monkeypatch):
    """Regression: a stale tuned plan carrying a tm that no longer divides M
    (e.g. the layer was re-pruned to a different channel count) must fall
    back to the pure-JAX path — never reach the kernel, even with asserts
    stripped (``python -O``)."""
    rng = np.random.default_rng(59)
    x = jnp.asarray(rng.standard_normal((1, 4, 10, 10)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)

    def _boom(*a, **kw):
        raise AssertionError("non-dividing tm reached the kernel")

    monkeypatch.setattr(ops, "sparse_conv_pallas", _boom)
    # fully-specified stale tiling: tm=3 does not divide m=8
    got = sparse_conv(x, ell, padding=1, tm=3, te=8, tf=8, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused epilogue: conv+bias+ReLU (+residual) vs the unfused dense oracle
# ---------------------------------------------------------------------------

def _epilogue_case(seed, n, c, h, w, m, r, *, dtype=jnp.float32, sp=0.7):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)), dtype=dtype)
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)), sp))
    bias = jnp.asarray(rng.standard_normal((m,)).astype(np.float32))
    return rng, x, wt, bias


def _unfused_oracle(x, wt, bias, *, stride, pad, residual=None):
    y = sparse_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    y = y.astype(jnp.float32) + bias[None, :, None, None]
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return jax.nn.relu(y)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("residual", [False, True])
def test_fused_epilogue_parity(stride, residual):
    """Fused conv+bias+ReLU (and +residual) vs the unfused dense oracle,
    with edge tiles: te/tf deliberately do not divide E/F."""
    n, c, h, w, m, r, pad = 2, 4, 13, 11, 8, 3, 1
    rng, x, wt, bias = _epilogue_case(1000 + 10 * stride + residual,
                                      n, c, h, w, m, r)
    ell = ell_from_dense_conv(wt)
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = (jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32))
           if residual else None)
    te, tf = max(1, (e + 1) // 2), max(1, f // 2 + 1)   # non-dividing tiles
    got = sparse_conv(x, ell, stride=stride, padding=pad, tm=4, te=te, tf=tf,
                      bias=bias, fuse_relu=True, residual=res, interpret=True)
    ref = _unfused_oracle(x, wt, bias, stride=stride, pad=pad, residual=res)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("residual", [False, True])
def test_fused_epilogue_parity_bf16(stride, residual):
    """bf16 inputs through the fused epilogue: the epilogue runs on the f32
    accumulator, so tolerance is the bf16 rounding of the conv itself."""
    import dataclasses
    n, c, h, w, m, r, pad = 1, 4, 12, 12, 8, 3, 1
    rng, x, wt, bias = _epilogue_case(2000 + 10 * stride + residual,
                                      n, c, h, w, m, r, dtype=jnp.bfloat16,
                                      sp=0.8)
    ell = ell_from_dense_conv(wt)
    ell = dataclasses.replace(ell, value=ell.value.astype(jnp.bfloat16))
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = (jnp.asarray(rng.standard_normal((n, m, e, f)), dtype=jnp.bfloat16)
           if residual else None)
    got = sparse_conv(x, ell, stride=stride, padding=pad,
                      bias=bias, fuse_relu=True, residual=res, interpret=True)
    ref = _unfused_oracle(x, wt, bias, stride=stride, pad=pad, residual=res)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_fused_epilogue_fallback_applies_epilogue(monkeypatch):
    """When no VMEM-feasible tiling exists, the fallback must still apply
    the full epilogue (bias + residual + ReLU), not just the conv."""
    n, c, h, w, m, r, pad = 1, 4, 10, 10, 8, 3, 1
    rng, x, wt, bias = _epilogue_case(3000, n, c, h, w, m, r)
    ell = ell_from_dense_conv(wt)
    e, f = out_spatial(h, w, r, r, 1, pad)
    res = jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32))
    monkeypatch.setattr(ops, "_VMEM_BUDGET", 1024)

    def _boom(*a, **kw):
        raise AssertionError("over-budget kernel launch")

    monkeypatch.setattr(ops, "sparse_conv_pallas", _boom)
    got = sparse_conv(x, ell, padding=pad, bias=bias, fuse_relu=True,
                      residual=res, interpret=True)
    ref = _unfused_oracle(x, wt, bias, stride=1, pad=pad, residual=res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_residual_tightens_vmem_feasibility(monkeypatch):
    """Reserving the residual input tile can rule out tilings that fit
    without it — tiling_fits must account the extra block."""
    from repro.kernels.sparse_conv.ops import tiling_fits
    args = dict(m=8, c=8, e=64, f=64, k=16, r=3, s=3, stride=1,
                tm=8, te=64, tf=64)
    # budget sized to fit input block + values + out tile, but not a second
    # out-tile-sized residual block
    x_bytes = 8 * 66 * 66 * 4
    out_bytes = 8 * 64 * 64 * 4
    monkeypatch.setattr(ops, "_VMEM_BUDGET",
                        x_bytes + 8 * 16 * 4 + out_bytes)
    assert tiling_fits(**args)
    assert not tiling_fits(**args, fuse_res=True)


def test_choose_tm_fits_budget():
    tm = choose_tm(m=256, c=96, hp=31, wp=31, e=27, f=27, k=256)
    assert 256 % tm == 0
    assert (96 * 31 * 31 * 4 + tm * 256 * 4 + tm * 27 * 27 * 4) <= 12 * 2**20


@pytest.mark.parametrize("pad_to", [1, 4, 8])
def test_fully_pruned_bank(pad_to):
    """Regression: an all-zero filter bank must keep K >= pad_to >= 1 and
    produce an all-zero output through the Pallas path (no 0-width arrays)."""
    wt = np.zeros((8, 4, 3, 3), np.float32)
    ell = ell_from_dense_conv(wt, pad_to=pad_to)
    assert ell.k >= max(1, pad_to)
    assert int(np.asarray(ell.nnz).sum()) == 0
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((1, 4, 8, 8)).astype(np.float32))
    got = sparse_conv(x, ell, padding=1, interpret=True)
    assert got.shape == (1, 8, 8, 8)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_degenerate_pad_to_clamped():
    """pad_to < 1 is clamped instead of crashing with ZeroDivisionError."""
    wt = np.zeros((4, 2, 3, 3), np.float32)
    assert ell_from_dense_conv(wt, pad_to=0).k >= 1


def test_empty_bank_rejected():
    with pytest.raises(ValueError):
        ell_from_dense_conv(np.zeros((0, 2, 3, 3), np.float32))

# ---------------------------------------------------------------------------
# quantised value streams: int8 / fp8 banks, in-kernel dequantisation
# ---------------------------------------------------------------------------

from repro.core.sparse_format import (QUANT_DTYPES, dequantize,  # noqa: E402
                                      quantize_values)


@pytest.mark.parametrize("value_dtype", sorted(QUANT_DTYPES))
@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("stride", [1, 2])
def test_quantised_bank_bit_identical_to_dequantised(value_dtype, pipeline,
                                                     stride):
    """The kernel's in-register dequantisation (scale at the FMA, f32
    accumulator) performs the exact multiply dequantize() does host-side,
    so a quantised bank through either schedule is bit-identical to the
    f32 kernel run on the dequantised bank — and within quantisation
    tolerance of the dense oracle.  Edge tiles (te/tf not dividing E/F)
    and the fused epilogue ride along."""
    n, c, h, w, m, r, pad = 2, 4, 13, 11, 8, 3, 1
    rng = np.random.default_rng(31000 + 100 * stride + 10 * pipeline
                                + len(value_dtype))
    x = jnp.asarray(rng.standard_normal((n, c, h, w)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)), 0.7))
    q = quantize_values(ell_from_dense_conv(wt), value_dtype)
    assert q.value_dtype == value_dtype
    bias = jnp.asarray(rng.standard_normal((m,)).astype(np.float32))
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32))
    te, tf = max(1, (e + 1) // 2), max(1, f // 2 + 1)   # non-dividing tiles
    kw = dict(stride=stride, padding=pad, tm=4, te=te, tf=tf, bias=bias,
              fuse_relu=True, residual=res, pipeline=pipeline, interpret=True)
    y_q = sparse_conv(x, q, **kw)
    y_f32 = sparse_conv(x, dequantize(q), **kw)
    np.testing.assert_array_equal(np.asarray(y_q), np.asarray(y_f32))
    ref = sparse_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    ref = np.asarray(jax.nn.relu(ref + bias[None, :, None, None] + res))
    rel = (np.linalg.norm(np.asarray(y_q) - ref) / np.linalg.norm(ref))
    assert rel < 0.05, rel


def test_quantised_balanced_bank_parity():
    """Quantisation composes with row balancing: scales follow the
    permuted rows, and the permuted quantised bank stays bit-identical to
    the f32 kernel on its dequantised twin."""
    rng = np.random.default_rng(31999)
    x = jnp.asarray(rng.standard_normal((1, 4, 10, 10)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.8))
    bal = balance_ell_conv(ell_from_dense_conv(wt))
    q = quantize_values(bal, "int8")
    assert q.perm is not None
    y_q = sparse_conv(x, q, padding=1, interpret=True)
    y_f32 = sparse_conv(x, dequantize(q), padding=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_q), np.asarray(y_f32))
    ref = np.asarray(sparse_conv_ref(x, jnp.asarray(wt), padding=1))
    rel = np.linalg.norm(np.asarray(y_q) - ref) / np.linalg.norm(ref)
    assert rel < 0.05, rel
