"""Pallas direct-sparse-conv kernel: interpret-mode sweeps vs the jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ell_from_dense_conv, magnitude_prune
from repro.kernels.sparse_conv.ops import choose_tm, sparse_conv
from repro.kernels.sparse_conv.ref import sparse_conv_ref

CASES = [
    # (N, C, H, W, M, R, pad, sparsity)
    (1, 3, 10, 10, 8, 3, 0, 0.7),
    (2, 8, 12, 12, 16, 3, 1, 0.9),
    (1, 4, 9, 9, 8, 5, 2, 0.8),
    (2, 16, 8, 8, 32, 1, 0, 0.85),   # 1x1
    (1, 2, 7, 11, 4, 3, 1, 0.5),     # non-square input
    (1, 6, 14, 14, 12, 3, 1, 0.0),   # fully dense weights via sparse path
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_oracle(case):
    n, c, h, w, m, r, pad, sp = case
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)).astype(np.float32))
    wt = rng.standard_normal((m, c, r, r)).astype(np.float32)
    if sp > 0:
        wt = np.asarray(magnitude_prune(jnp.asarray(wt), sp))
    ell = ell_from_dense_conv(wt)
    got = sparse_conv(x, ell, padding=pad, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=pad)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 4, 10, 10)), dtype=dtype)
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.8))
    ell = ell_from_dense_conv(wt.astype(np.float32))
    import dataclasses
    ell = dataclasses.replace(ell, value=ell.value.astype(dtype))
    got = sparse_conv(x, ell, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), padding=1)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("tm", [1, 2, 4, 8])
def test_kernel_channel_tiles(tm):
    """Every channel-tile size produces identical results."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 4, 8, 8)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 4, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    got = sparse_conv(x, ell, tm=tm, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_strided_fallback():
    """stride > 1 uses the pure-JAX direct path (kernel customisation)."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((1, 3, 16, 16)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 3, 3, 3)).astype(np.float32)), 0.7))
    ell = ell_from_dense_conv(wt)
    got = sparse_conv(x, ell, stride=2, padding=1, interpret=True)
    ref = sparse_conv_ref(x, jnp.asarray(wt), stride=2, padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_choose_tm_fits_budget():
    tm = choose_tm(m=256, c=96, hp=31, wp=31, e=27, f=27, k=256)
    assert 256 % tm == 0
    assert (96 * 31 * 31 * 4 + tm * 256 * 4 + tm * 27 * 27 * 4) <= 12 * 2**20


@pytest.mark.parametrize("pad_to", [1, 4, 8])
def test_fully_pruned_bank(pad_to):
    """Regression: an all-zero filter bank must keep K >= pad_to >= 1 and
    produce an all-zero output through the Pallas path (no 0-width arrays)."""
    wt = np.zeros((8, 4, 3, 3), np.float32)
    ell = ell_from_dense_conv(wt, pad_to=pad_to)
    assert ell.k >= max(1, pad_to)
    assert int(np.asarray(ell.nnz).sum()) == 0
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((1, 4, 8, 8)).astype(np.float32))
    got = sparse_conv(x, ell, padding=1, interpret=True)
    assert got.shape == (1, 8, 8, 8)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_degenerate_pad_to_clamped():
    """pad_to < 1 is clamped instead of crashing with ZeroDivisionError."""
    wt = np.zeros((4, 2, 3, 3), np.float32)
    assert ell_from_dense_conv(wt, pad_to=0).k >= 1


def test_empty_bank_rejected():
    with pytest.raises(ValueError):
        ell_from_dense_conv(np.zeros((0, 2, 3, 3), np.float32))
