"""Flash attention Pallas kernel: interpret-mode sweeps vs the jnp oracle,
including GQA grouping, causality, gradients, and the sharded dispatcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention

pytestmark = pytest.mark.pallas
from repro.kernels.flash_attention.ops import flash_attention_bthd
from repro.kernels.flash_attention.ref import attention_ref

CASES = [
    # (B, H, KV, T, S, D, causal, cq, ck)
    (1, 4, 4, 32, 32, 16, True, 16, 16),
    (2, 4, 2, 64, 64, 16, True, 16, 16),    # GQA g=2
    (1, 8, 1, 32, 32, 8, True, 8, 8),       # MQA
    (2, 2, 2, 32, 32, 16, False, 16, 16),   # bidirectional
    (1, 4, 4, 64, 64, 32, True, 32, 64),    # cq != ck
]


@pytest.mark.parametrize("case", CASES)
def test_fwd_matches_oracle(case):
    b, h, kv, t, s, d, causal, cq, ck = case
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, h, t, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)).astype(np.float32))
    out = flash_attention(q, k, v, d ** -0.5, causal, cq, ck, True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES[:3])
def test_grads_match_oracle(case):
    b, h, kv, t, s, d, causal, cq, ck = case
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, h, t, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)).astype(np.float32))
    co = jnp.asarray(rng.standard_normal((b, h, t, d)).astype(np.float32))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, d ** -0.5, causal, cq, ck,
                                       True) * co)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal) * co)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_bf16_fwd():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 4, 32, 16)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, 0.25, True, 16, 16, True)
    ref = attention_ref(q, k, v, causal=True, scale=0.25)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_bthd_wrapper_layout():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((2, 32, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((2, 32, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((2, 32, 2, 16)).astype(np.float32))
    out = flash_attention_bthd(q, k, v, causal=True, chunk=16, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.transpose(0, 2, 1, 3)),
                               rtol=2e-5, atol=2e-5)


def test_model_dispatcher_flash_equals_chunked():
    """full_attention under flags.ATTN_IMPL toggling (no mesh)."""
    from repro.models import flags as F
    from repro.models.layers import full_attention
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 32, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((2, 32, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((2, 32, 2, 16)).astype(np.float32))
    ref = full_attention(q, k, v, causal=True)
    F.set_attn_impl("flash")
    try:
        got = full_attention(q, k, v, causal=True)
    finally:
        F.set_attn_impl("chunked")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
