"""Kernel-customization autotuner: space validity, cache round-trip,
and method="auto" numerical equivalence (interpret mode, CPU)."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sparse_conv.ops import (SMEM_BUDGET, VMEM_BUDGET,
                                           choose_tm, halo_extent,
                                           tiling_fits, tm_candidates)
from repro.models import cnn
from repro.tuning import (Candidate, ConvGeometry, PlanCache, PlanEntry,
                          apply_plan_to_params, enumerate_candidates,
                          layer_key, plan_network, roofline_estimate)


def _geom(**kw):
    base = dict(name="l", m=64, c=32, h=14, w=14, r=3, s=3, stride=1, pad=1,
                sparsity=0.7, batch=2)
    base.update(kw)
    return ConvGeometry(**base)


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------

def _assert_pallas_fits(g, cands):
    """Every pallas candidate's (tm, te, tf) halo'd working set fits VMEM —
    fused candidates accounting the residual input tile, pipelined ones the
    second halo scratch buffer — and the three scalar-prefetch operands
    (packed indices + nnz row + bias row) fit SMEM."""
    assert any(c.method == "pallas" for c in cands)
    for cd in cands:
        if cd.method != "pallas":
            continue
        assert g.m % cd.tm == 0
        assert cd.te is not None and cd.tf is not None
        k = g.k_est(cd.pad_to)
        x_bytes = (g.c * halo_extent(cd.te, g.stride, g.r)
                   * halo_extent(cd.tf, g.stride, g.s) * 4)
        if cd.pipeline:
            x_bytes *= 2
        out_bytes = cd.tm * cd.te * cd.tf * 4
        res_bytes = out_bytes if (cd.fuse and g.residual) else 0
        assert x_bytes + cd.tm * k * 4 + out_bytes + res_bytes <= VMEM_BUDGET
        assert tiling_fits(g.m, g.c, g.e, g.f, k, g.r, g.s, g.stride,
                           cd.tm, cd.te, cd.tf,
                           fuse_res=cd.fuse and g.residual,
                           pipeline=cd.pipeline)
        assert g.m * (k + 2) * 4 <= SMEM_BUDGET


def test_candidates_tiles_divide_m_and_fit_budgets():
    g = _geom()
    _assert_pallas_fits(g, enumerate_candidates(g))


def test_dense_layer_space_is_dense_only():
    assert enumerate_candidates(_geom(sparsity=0.0)) == [Candidate("dense")]


def test_strided_layer_has_pallas():
    """Strided layers are pallas-eligible now — the kernel strides in-kernel."""
    g = _geom(stride=2)
    _assert_pallas_fits(g, enumerate_candidates(g))


def test_large_map_layer_gets_spatially_tiled_pallas():
    """A layer whose whole padded image busts VMEM still gets pallas
    candidates — spatially tiled ones, all within budget."""
    g = _geom(m=8, c=96, h=192, w=192, pad=1, sparsity=0.95)
    assert g.c * g.hp * g.wp * 4 > VMEM_BUDGET
    cands = enumerate_candidates(g)
    _assert_pallas_fits(g, cands)
    assert all(cd.te < g.e or cd.tf < g.f
               for cd in cands if cd.method == "pallas")


def test_smem_heavy_layer_has_no_pallas():
    # m*k*4 far over the SMEM budget: huge M, near-dense rows.
    g = _geom(m=8192, c=512, sparsity=0.05)
    assert all(c.method != "pallas" for c in enumerate_candidates(g))


def test_choose_tm_is_first_candidate():
    args = dict(m=256, c=96, hp=31, wp=31, e=27, f=27, k=256)
    assert choose_tm(**args) == tm_candidates(**args)[0]


def test_roofline_orders_sparse_below_dense():
    """The execution-unit split (VPU for per-nonzero FMA loops, MXU for
    dense/bsr contractions) moves the dense-vs-direct crossover: at 95%
    sparsity the direct method's bound still beats dense, but at a
    moderate 70% the VPU-priced scan loses to the MXU-fed dense conv on a
    compute-heavy geometry — the gap the bsr method exists to close."""
    g_hi = _geom(m=256, c=256, h=28, w=28, sparsity=0.95)
    t_dense = roofline_estimate(g_hi, Candidate("dense"))
    t_direct = roofline_estimate(g_hi, Candidate("csr-direct", pad_to=8))
    assert t_direct < t_dense
    g_mid = _geom(m=256, c=256, h=28, w=28, sparsity=0.7)
    assert (roofline_estimate(g_mid, Candidate("csr-direct", pad_to=8))
            > roofline_estimate(g_mid, Candidate("dense")))


def test_roofline_pallas_tm_amortises_input():
    g = _geom()
    t1 = roofline_estimate(g, Candidate("pallas", tm=1, pad_to=8))
    t64 = roofline_estimate(g, Candidate("pallas", tm=64, pad_to=8))
    assert t64 <= t1


def test_roofline_pallas_spatial_tiling_costs_halo():
    """Smaller spatial tiles re-fetch halo rows: the untiled schedule must
    score no worse than a tiled one on a memory-bound geometry that fits."""
    g = _geom()
    t_full = roofline_estimate(g, Candidate("pallas", tm=8, pad_to=8))
    t_tiled = roofline_estimate(g, Candidate("pallas", tm=8, pad_to=8,
                                             te=8, tf=8))
    assert t_full <= t_tiled


# ---------------------------------------------------------------------------
# fuse axis (in-kernel epilogue)
# ---------------------------------------------------------------------------

def test_candidates_include_fused_variants():
    g = _geom(relu=True)
    cands = enumerate_candidates(g)
    fused = [c for c in cands if c.method == "pallas" and c.fuse]
    unfused = [c for c in cands if c.method == "pallas" and not c.fuse]
    assert fused and unfused
    _assert_pallas_fits(g, cands)


def test_candidates_fused_residual_fit_vmem():
    g = _geom(relu=True, residual=True)
    _assert_pallas_fits(g, enumerate_candidates(g))


def test_roofline_credits_fused_epilogue():
    """The fused epilogue removes full output-tensor passes, so on a
    memory-bound geometry the fused candidate must score strictly better."""
    g = _geom(relu=True, residual=True)
    base = dict(tm=8, pad_to=8)
    t_unfused = roofline_estimate(g, Candidate("pallas", **base))
    t_fused = roofline_estimate(g, Candidate("pallas", **base, fuse=True))
    assert t_fused < t_unfused


def test_layer_key_separates_epilogues():
    """Same geometry, different fused epilogue -> different cache keys, so
    fused and unfused variants never share a measurement."""
    plain = layer_key(_geom(), "cpu")
    relu = layer_key(_geom(relu=True), "cpu")
    tail = layer_key(_geom(relu=True, residual=True), "cpu")
    assert len({plain, relu, tail}) == 3


def test_plan_program_dedups_on_op_geometry():
    """Repeated identical bottlenecks are scored once per run (even with no
    persistent cache), while the fused-tail conv — same shape as a plain
    conv+ReLU elsewhere — gets its own entry."""
    from repro.engine import lower
    from repro.tuning import plan_program

    body = lambda i: cnn.Residual(body=(                       # noqa: E731
        cnn.Conv(f"b{i}/1x1a", 16, 1, sparsity=0.7), cnn.Relu(),
        cnn.Conv(f"b{i}/1x1b", 16, 1, sparsity=0.7)))
    net = [cnn.Conv("stem", 16, 3, 1, 1, sparsity=0.0), cnn.Relu(),
           body(0), cnn.Relu(), body(1), cnn.Relu()]
    program = lower(net, (3, 12, 12))
    calls = []
    import repro.tuning.planner as planner_mod
    orig = planner_mod.plan_layer

    def spy(g, **kw):
        calls.append(g.name)
        return orig(g, **kw)

    planner_mod.plan_layer, plan = spy, None
    try:
        plan = planner_mod.plan_program(program, batch=1, mode="roofline")
    finally:
        planner_mod.plan_layer = orig
    # 4 sparse convs, but only 2 distinct (geometry, epilogue) keys:
    # the relu'd 1x1a and the shortcut-fused 1x1b tail
    assert len(plan) == 5
    assert len(calls) == 2
    assert plan["b0/1x1a"] == plan["b1/1x1a"]
    assert plan["b0/1x1b"] == plan["b1/1x1b"]


# ---------------------------------------------------------------------------
# pipeline axis (double-buffered halo DMA) + permute axis (balanced banks)
# ---------------------------------------------------------------------------

def test_candidates_include_pipeline_and_permute_variants():
    g = _geom()
    cands = [c for c in enumerate_candidates(g) if c.method == "pallas"]
    assert any(c.pipeline for c in cands)
    assert any(not c.pipeline for c in cands)
    assert any(c.permute for c in cands)
    assert any(not c.permute for c in cands)
    _assert_pallas_fits(g, cands)


def test_pipelined_tilings_reserve_second_halo_buffer(monkeypatch):
    """A tiling whose single halo block fits but whose doubled block busts
    VMEM must be blocking-only in the candidate space."""
    import repro.kernels.sparse_conv.ops as ops
    args = dict(m=8, c=8, e=64, f=64, k=16, r=3, s=3, stride=1,
                tm=8, te=64, tf=64)
    x_bytes = 8 * 66 * 66 * 4
    monkeypatch.setattr(ops, "_VMEM_BUDGET",
                        x_bytes + 8 * 16 * 4 + 8 * 64 * 64 * 4)
    assert tiling_fits(**args)
    assert not tiling_fits(**args, pipeline=True)


def test_roofline_credits_pipelined_staging():
    """Double-buffered staging overlaps the halo copies with compute: on a
    staging-heavy tiling the pipelined candidate must score no worse, and
    its exposed staged-input stall must be strictly smaller."""
    from repro.tuning import staging_stall_s

    g = _geom()
    base = dict(tm=8, pad_to=8, te=8, tf=8)
    blocking = Candidate("pallas", **base)
    pipelined = Candidate("pallas", **base, pipeline=True)
    assert roofline_estimate(g, pipelined) <= roofline_estimate(g, blocking)
    assert staging_stall_s(g, pipelined) < staging_stall_s(g, blocking)


def test_roofline_charges_permute_gather_only():
    """The kernel's per-row nnz loop makes tile compute permutation-
    invariant (rows run sequentially on the TPU grid), so the roofline must
    NOT fabricate a compute credit for balanced banks: the permute
    candidate pays exactly its inverse-permutation gather and scores no
    better analytically — any unrolled-loop scheduling benefit is wall-mode
    territory."""
    from repro.tuning import permute_bytes

    g = _geom()
    base = dict(tm=8, pad_to=8)
    t_nat = roofline_estimate(g, Candidate("pallas", **base))
    t_perm = roofline_estimate(g, Candidate("pallas", **base, permute=True))
    assert permute_bytes(g, True) > permute_bytes(g, False) == 0.0
    assert t_perm >= t_nat
    # memory-bound geometry: the gather round-trip is visible
    assert t_perm > t_nat


def test_plan_entry_carries_pipeline_and_permute():
    pe = PlanEntry(method="pallas", tm=8, te=8, tf=8, pad_to=8,
                   pipeline=True, permute=True)
    assert pe.candidate.pipeline and pe.candidate.permute
    d = pe.to_dict()
    assert d["pipeline"] is True and d["permute"] is True
    assert PlanEntry.from_dict(d) == pe


# ---------------------------------------------------------------------------
# bsr axis (BCSR MXU conv): block-shape candidates + MXU-vs-VPU crossover
# ---------------------------------------------------------------------------

def test_candidates_include_bsr_block_shapes():
    """Sparse layers get bsr candidates across the block ladder, each with
    a VMEM-feasible spatial tiling and fused/unfused variants; tm, pad_to
    and the pallas-only schedule flags stay unset on them."""
    from repro.kernels.bsr_conv.ops import bsr_tiling_fits

    g = _geom()
    cands = [c for c in enumerate_candidates(g) if c.method == "bsr"]
    assert cands
    assert {(c.block_m, c.block_n) for c in cands} >= {(8, 128), (16, 128)}
    assert any(c.fuse for c in cands) and any(not c.fuse for c in cands)
    for cd in cands:
        assert cd.tm is None and cd.pad_to is None
        assert not cd.pipeline and not cd.permute
        assert cd.te is not None and cd.tf is not None
        assert bsr_tiling_fits(g.c, g.r, g.s, g.stride, cd.block_m,
                               cd.block_n, cd.te, cd.tf,
                               fuse_res=cd.fuse and g.residual)


def test_roofline_bsr_beats_vpu_on_moderate_sparsity():
    """The crossover the bsr path exists for: on a compute-heavy layer at
    moderate (~62%) sparsity, the MXU-priced bsr bound must beat the best
    VPU-priced ELL pallas bound and the dense bound — while at extreme
    sparsity the per-nonzero ELL loop does so little work it wins back."""
    g = _geom(m=192, c=64, h=56, w=56, sparsity=0.62, batch=1)
    cands = enumerate_candidates(g)
    t_bsr = min(roofline_estimate(g, c) for c in cands if c.method == "bsr")
    t_ell = min(roofline_estimate(g, c) for c in cands if c.method == "pallas")
    t_dense = roofline_estimate(g, Candidate("dense"))
    assert t_bsr < t_ell and t_bsr < t_dense
    g_hi = _geom(m=192, c=64, h=56, w=56, sparsity=0.98, batch=1)
    cands_hi = enumerate_candidates(g_hi)
    t_bsr_hi = min(roofline_estimate(g_hi, c)
                   for c in cands_hi if c.method == "bsr")
    t_ell_hi = min(roofline_estimate(g_hi, c)
                   for c in cands_hi if c.method == "pallas")
    assert t_ell_hi < t_bsr_hi


def test_roofline_bsr_bigger_bm_amortises_gather():
    """The tile-gather-vs-systolic tradeoff: with identical spatial tiling
    and kept-block fraction, a taller block (bigger bm) amortises the VPU
    patch gather over more MXU rows, so its compute term is no worse."""
    from repro.tuning.measure import _bsr_terms

    g = _geom(m=256, c=256, h=28, w=28, sparsity=0.6)
    t8, _, _ = _bsr_terms(g, Candidate("bsr", te=28, tf=28,
                                       block_m=8, block_n=128))
    t64, _, _ = _bsr_terms(g, Candidate("bsr", te=28, tf=28,
                                        block_m=64, block_n=128))
    assert t64 <= t8


def test_plan_entry_carries_block_shape():
    pe = PlanEntry(method="bsr", te=16, tf=16, fuse=True,
                   block_m=32, block_n=128)
    assert pe.candidate.block_m == 32 and pe.candidate.block_n == 128
    d = pe.to_dict()
    assert d["block_m"] == 32 and d["block_n"] == 128
    assert PlanEntry.from_dict(d) == pe


def test_auto_executes_bsr_plan():
    """A plan entry pinning the bsr method — block shape, spatial tiling,
    fused epilogue — must execute through method="auto" (interpret mode)
    and match the dense oracle, both with the bank prebuilt by
    apply_plan_to_params and blocked at trace time without it."""
    net = [cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
           cnn.Conv("c1", 16, 3, 1, 1, sparsity=0.7), cnn.Relu()]
    rng = np.random.default_rng(31)
    params = cnn.init_cnn(net, 3, rng, 10)
    x = jnp.asarray(rng.standard_normal((1, 3, 10, 10)).astype(np.float32))
    plan = {"c0": PlanEntry(method="dense"),
            "c1": PlanEntry(method="bsr", te=6, tf=6, fuse=True,
                            block_m=8, block_n=32)}
    y_dense = cnn.cnn_forward(net, params, x, method="dense")
    # without apply_plan_to_params: the engine blocks the bank at trace time
    y_auto = cnn.cnn_forward(net, params, x, method="auto", plan=plan)
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)
    # with it: the prebuilt bcsr_auto bank is used
    apply_plan_to_params(params, plan)
    assert params["c1"]["bcsr_auto"].block == (8, 32)
    y_auto2 = cnn.cnn_forward(net, params, x, method="auto", plan=plan)
    np.testing.assert_allclose(np.asarray(y_auto2), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_roofline_with_weights_recosts_bsr_from_actual_bank():
    """Regression: the geometry-only bsr estimate assumes block-structured
    pruning, but an unstructured magnitude-pruned bank keeps nearly every
    tile.  Weights-aware roofline planning must price bsr at the true
    kept-block count: the estimate-vs-honest bound gap must show, and on a
    high-sparsity layer — where the cheap per-nonzero ELL loop is the real
    winner — the winner must flip off the MXU path the estimate picked."""
    from repro.core import block_prune_conv, magnitude_prune
    from repro.tuning import plan_layer
    from repro.tuning.measure import bcsr_true_kept

    g = ConvGeometry(name="l", m=256, c=256, h=14, w=14, r=3, s=3, stride=1,
                     pad=1, sparsity=0.9, batch=1)
    rng = np.random.default_rng(37)
    w = np.asarray(magnitude_prune(jnp.asarray(
        rng.standard_normal((256, 256, 3, 3)).astype(np.float32)), 0.9))
    # unstructured pruning keeps essentially every (8, 128) tile
    gbn = -(-256 * 9 // 128)
    assert bcsr_true_kept(w, 8, 128) > 0.9 * gbn
    # the estimate prices bsr at ~10% of the tiles and picks it...
    assert plan_layer(g, mode="roofline").method == "bsr"
    # ...the true near-dense bank costs more, and the winner flips
    cand = Candidate("bsr", te=14, tf=14, block_m=8, block_n=128)
    assert (roofline_estimate(g, cand, w_dense=w)
            > roofline_estimate(g, cand))
    assert plan_layer(g, mode="roofline", w_dense=w).method != "bsr"
    # a genuinely block-pruned bank keeps the MXU pick
    wb = np.asarray(block_prune_conv(jnp.asarray(
        rng.standard_normal((256, 256, 3, 3)).astype(np.float32)),
        0.9, (8, 128)))
    assert plan_layer(g, mode="roofline", w_dense=wb).method == "bsr"


def test_weights_aware_plan_reads_legacy_untagged_entries(monkeypatch):
    """Regression: weights-aware plans key on layer_key + a weight-structure
    tag, but pre-tag caches (v1-v4 migrations, weight-free v5 runs) are
    untagged.  A non-bsr legacy winner must be inherited without
    re-scoring — only bsr entries are structure-sensitive and must be
    re-scored under the tagged key."""
    from repro.core import magnitude_prune
    from repro.engine import lower
    import repro.tuning.planner as planner_mod
    from repro.tuning import plan_program

    # tiny geometry: the weight-free roofline winner here is not bsr
    net = [cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.7), cnn.Relu()]
    program = lower(net, (3, 10, 10))
    cache = PlanCache()
    plan0 = plan_program(program, batch=1, mode="roofline", cache=cache)
    assert plan0["c1"].method != "bsr"
    legacy_keys = set(cache.entries)
    assert not any("_bk" in k for k in legacy_keys)

    rng = np.random.default_rng(43)
    params = cnn.init_cnn(net, 3, rng, 10)
    calls = []
    orig = planner_mod.plan_layer
    monkeypatch.setattr(planner_mod, "plan_layer",
                        lambda g, **kw: calls.append(g.name) or orig(g, **kw))
    plan1 = plan_program(program, batch=1, mode="roofline", cache=cache,
                         params=params)
    # the untagged non-bsr entry was inherited: zero re-scoring, same plan
    assert calls == []
    assert plan1["c1"] == plan0["c1"]
    assert set(cache.entries) == legacy_keys

    # a legacy *bsr* entry must NOT be inherited across structures: plant
    # one at the untagged key of a geometry whose estimate picks bsr
    net2 = [cnn.Conv("c2", 192, 3, 1, 1, sparsity=0.62), cnn.Relu()]
    program2 = lower(net2, (64, 56, 56))
    cache2 = PlanCache()
    plan2 = plan_program(program2, batch=1, mode="roofline", cache=cache2)
    assert plan2["c2"].method == "bsr"
    calls.clear()
    params2 = {"c2": {"w": jnp.asarray(np.asarray(magnitude_prune(jnp.asarray(
        np.random.default_rng(0).standard_normal(
            (192, 64, 3, 3)).astype(np.float32)), 0.62))),
        "b": jnp.zeros((192,), jnp.float32)}}
    plan_program(program2, batch=1, mode="roofline", cache=cache2,
                 params=params2)
    assert calls == ["c2"]  # re-scored under the structure-tagged key
    assert any("_bk" in k for k in cache2.entries)


def test_auto_plan_uses_bound_params_for_bsr_costing(monkeypatch):
    """The engine's self-tuned roofline plan must pass its bound params so
    bsr costing sees the actual bank structure."""
    from repro.engine import CnnEngine, lower
    import repro.tuning.planner as planner_mod

    net = [cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.7), cnn.Relu()]
    rng = np.random.default_rng(41)
    params = cnn.init_cnn(net, 3, rng, 10)
    engine = CnnEngine(lower(net, (3, 10, 10)), params)
    seen = {}
    orig = planner_mod.plan_program

    def spy(program, **kw):
        seen["params"] = kw.get("params")
        return orig(program, **kw)

    monkeypatch.setattr(planner_mod, "plan_program", spy)
    engine._auto_plan(1)
    assert seen["params"] is params


def test_wall_mode_excludes_bsr_off_tpu():
    """Like the ELL pallas kernel, the bsr kernel is interpret-mode off-TPU
    — wall-timing it would measure Python, so it is not measurable."""
    from repro.tuning import measurable

    assert not measurable(Candidate("bsr", block_m=8, block_n=128), "cpu")
    assert measurable(Candidate("bsr", block_m=8, block_n=128), "tpu")
    assert measurable(Candidate("csr-direct", pad_to=8), "cpu")


# ---------------------------------------------------------------------------
# cache / planner round-trip
# ---------------------------------------------------------------------------

def test_sparsity_bucketing_shares_keys():
    a = layer_key(_geom(sparsity=0.69), "cpu")
    b = layer_key(_geom(sparsity=0.71), "cpu")
    c = layer_key(_geom(sparsity=0.50), "cpu")
    assert a == b != c


def test_plan_cache_roundtrip(tmp_path):
    path = str(tmp_path / "plans" / "cache.json")
    net = cnn.alexnet()
    cache = PlanCache(path)
    plan = plan_network(net, 3, 99, batch=1, mode="roofline", cache=cache)
    assert len(cache) > 0
    # tune -> serialize -> reload -> identical plan, with zero re-tuning
    # (a miss would write the file again; compare entries directly).
    reloaded = PlanCache(path)
    assert reloaded.entries == cache.entries
    replan = plan_network(net, 3, 99, batch=1, mode="roofline", cache=reloaded)
    assert replan == plan
    # every sparse layer got a tuned sparse method under roofline scoring
    for layer, _ in cnn.conv_layer_shapes(net, 3, 99):
        pe = plan[layer.name]
        assert isinstance(pe, PlanEntry)
        if layer.sparsity == 0:
            assert pe.method == "dense"


def test_plan_cache_version_guard(tmp_path):
    """An unknown schema version warns and falls back to an empty cache by
    default (a stale cache must not take a deploy down); strict load keeps
    the historical ValueError for tooling that wants to localise it."""
    from repro.tuning.cache import PlanCacheWarning

    path = tmp_path / "bad.json"
    path.write_text('{"version": 999, "entries": {}}')
    with pytest.warns(PlanCacheWarning, match="version 999"):
        cache = PlanCache(str(path))
    assert len(cache) == 0
    with pytest.raises(ValueError, match="version 999"):
        PlanCache().load(str(path), strict=True)


@pytest.mark.parametrize("text, match", [
    ('{"version": 5, "entries": {', "Expecting"),      # truncated mid-write
    ("not json at all", "Expecting"),                  # corrupt
    ("[1, 2, 3]", "not a JSON object"),                # wrong document shape
    ('{"version": 5, "entries": [1]}', "not an object"),  # wrong entries shape
])
def test_plan_cache_mangled_file_falls_back_empty(tmp_path, text, match):
    """Corrupt/truncated cache files emit a diagnostic and fall back to an
    empty cache instead of raising mid-deploy; strict load raises."""
    from repro.tuning.cache import PlanCacheWarning

    path = tmp_path / "mangled.json"
    path.write_text(text)
    with pytest.warns(PlanCacheWarning, match=match):
        cache = PlanCache(str(path))
    assert len(cache) == 0
    with pytest.raises((ValueError, json.JSONDecodeError)):
        PlanCache().load(str(path), strict=True)


def test_plan_cache_malformed_entry_dropped(tmp_path):
    """A single malformed entry is dropped with a warning; healthy siblings
    survive the load."""
    from repro.tuning.cache import CACHE_VERSION, PlanCacheWarning

    path = tmp_path / "partial.json"
    path.write_text(json.dumps({
        "version": CACHE_VERSION,
        "entries": {
            "good": {"method": "dense"},
            "bad": {"tm": 64},          # missing required "method"
            "worse": "not-a-dict",
        }}))
    with pytest.warns(PlanCacheWarning, match="dropped 2 malformed"):
        cache = PlanCache(str(path))
    assert set(cache.entries) == {"good"}
    assert cache.entries["good"].method == "dense"
    with pytest.raises(ValueError, match="malformed"):
        PlanCache().load(str(path), strict=True)


def test_plan_cache_load_errors_counter(tmp_path):
    """Non-strict load failures bump the tuning.cache.load_errors counter
    when telemetry is enabled."""
    from repro import telemetry
    from repro.tuning.cache import PlanCacheWarning

    path = tmp_path / "bad.json"
    path.write_text("garbage")
    with telemetry.enabled():
        before = telemetry.counter("tuning.cache.load_errors").value
        with pytest.warns(PlanCacheWarning):
            PlanCache(str(path))
        after = telemetry.counter("tuning.cache.load_errors").value
    assert after == before + 1


def test_plan_cache_v1_migration(tmp_path):
    """v1 documents (no te/tf, no fuse, no pipeline/permute) load via
    migration: entries get te=tf=None — the untiled schedule the v1 kernel
    ran — fuse=False (the unfused epilogue) and pipeline=permute=False
    (blocking DMA, natural row order), and re-save as the current version."""
    import json

    from repro.tuning.cache import CACHE_VERSION

    path = tmp_path / "v1.json"
    path.write_text(json.dumps({
        "version": 1,
        "entries": {"k1": {"method": "pallas", "tm": 64, "pad_to": 8,
                           "est_s": 1e-5, "source": "roofline"}}}))
    cache = PlanCache(str(path))
    pe = cache.get("k1")
    assert pe == PlanEntry(method="pallas", tm=64, pad_to=8, te=None, tf=None,
                           fuse=False, pipeline=False, permute=False,
                           est_s=1e-5, source="roofline")
    assert pe.candidate.te is None and pe.candidate.tf is None
    assert pe.candidate.fuse is False
    assert pe.candidate.pipeline is False and pe.candidate.permute is False
    assert pe.candidate.block_m is None and pe.candidate.block_n is None
    out = tmp_path / "v6.json"
    cache.save(str(out))
    doc = json.loads(out.read_text())
    assert doc["version"] == CACHE_VERSION == 6
    assert doc["entries"]["k1"]["te"] is None
    assert doc["entries"]["k1"]["fuse"] is False
    assert doc["entries"]["k1"]["value_dtype"] == "float32"
    assert doc["entries"]["k1"]["pipeline"] is False
    assert doc["entries"]["k1"]["permute"] is False
    assert doc["entries"]["k1"]["block_m"] is None
    # and the migrated file round-trips as current-version
    assert PlanCache(str(out)).get("k1") == pe


def test_plan_cache_v2_migration_roundtrip(tmp_path):
    """v2 documents (te/tf but no fuse/pipeline/permute) load via migration
    — entries get fuse=False (the unfused three-pass epilogue) and
    pipeline=permute=False (the v2 kernel's blocking single-buffer DMA) —
    and the re-saved v6 file round-trips identically."""
    import json

    from repro.tuning.cache import CACHE_VERSION

    path = tmp_path / "v2.json"
    path.write_text(json.dumps({
        "version": 2,
        "entries": {
            "kp": {"method": "pallas", "tm": 32, "te": 16, "tf": 16,
                   "pad_to": 4, "est_s": 2e-5, "source": "measured"},
            "kd": {"method": "dense", "est_s": 0.0, "source": "heuristic"},
        }}))
    cache = PlanCache(str(path))
    pe = cache.get("kp")
    assert pe == PlanEntry(method="pallas", tm=32, te=16, tf=16, pad_to=4,
                           fuse=False, pipeline=False, permute=False,
                           est_s=2e-5, source="measured")
    assert cache.get("kd").fuse is False
    out = tmp_path / "migrated.json"
    cache.save(str(out))
    doc = json.loads(out.read_text())
    assert doc["version"] == CACHE_VERSION == 6
    assert doc["entries"]["kp"]["fuse"] is False
    assert doc["entries"]["kp"]["pipeline"] is False
    assert doc["entries"]["kp"]["value_dtype"] == "float32"
    reloaded = PlanCache(str(out))
    assert reloaded.entries == cache.entries


def test_plan_cache_v3_migration_roundtrip(tmp_path):
    """v3 documents (fuse but no pipeline/permute) load via migration —
    entries keep their fuse flag and get pipeline=permute=False, the
    blocking natural-order schedule every v3 kernel ran — and the re-saved
    v6 file round-trips identically."""
    import json

    from repro.tuning.cache import CACHE_VERSION

    path = tmp_path / "v3.json"
    path.write_text(json.dumps({
        "version": 3,
        "entries": {
            "kf": {"method": "pallas", "tm": 16, "te": 32, "tf": 32,
                   "pad_to": 8, "fuse": True, "est_s": 3e-5,
                   "source": "measured"},
            "kd": {"method": "csr-direct", "pad_to": 4, "est_s": 1e-4,
                   "source": "roofline"},
        }}))
    cache = PlanCache(str(path))
    pe = cache.get("kf")
    assert pe == PlanEntry(method="pallas", tm=16, te=32, tf=32, pad_to=8,
                           fuse=True, pipeline=False, permute=False,
                           est_s=3e-5, source="measured")
    assert cache.get("kd").pipeline is False
    out = tmp_path / "migrated.json"
    cache.save(str(out))
    doc = json.loads(out.read_text())
    assert doc["version"] == CACHE_VERSION == 6
    assert doc["entries"]["kf"]["fuse"] is True
    assert doc["entries"]["kf"]["pipeline"] is False
    assert doc["entries"]["kf"]["permute"] is False
    assert PlanCache(str(out)).entries == cache.entries


def test_plan_cache_v4_migration_roundtrip(tmp_path):
    """v4 documents (pipeline/permute but no block shape) load via
    migration — entries keep their schedule flags and get block_m =
    block_n = None (no pre-v5 kernel ran blocked) — and the re-saved v6
    file round-trips identically."""
    import json

    from repro.tuning.cache import CACHE_VERSION

    path = tmp_path / "v4.json"
    path.write_text(json.dumps({
        "version": 4,
        "entries": {
            "kp": {"method": "pallas", "tm": 8, "te": 16, "tf": 16,
                   "pad_to": 8, "fuse": True, "pipeline": True,
                   "permute": True, "est_s": 4e-5, "source": "measured"},
        }}))
    cache = PlanCache(str(path))
    pe = cache.get("kp")
    assert pe == PlanEntry(method="pallas", tm=8, te=16, tf=16, pad_to=8,
                           fuse=True, pipeline=True, permute=True,
                           block_m=None, block_n=None,
                           est_s=4e-5, source="measured")
    out = tmp_path / "migrated.json"
    cache.save(str(out))
    doc = json.loads(out.read_text())
    assert doc["version"] == CACHE_VERSION == 6
    assert doc["entries"]["kp"]["pipeline"] is True
    assert doc["entries"]["kp"]["block_m"] is None
    assert doc["entries"]["kp"]["value_dtype"] == "float32"
    assert PlanCache(str(out)).entries == cache.entries


def test_plan_cache_migration_chain_v1_to_v6(tmp_path):
    """The full migration chain: one fixture per historical schema (v1-v5)
    loads, defaults exactly the fields its kernels predate, re-persists as
    v6, and the v6 file round-trips bit-for-bit. Every pre-v6 entry streams
    f32 values, so migration pins value_dtype="float32"."""
    import json

    from repro.tuning.cache import CACHE_VERSION, MIGRATABLE_VERSIONS

    fixtures = {
        1: ({"method": "pallas", "tm": 64, "pad_to": 8},
            PlanEntry(method="pallas", tm=64, pad_to=8)),
        2: ({"method": "pallas", "tm": 32, "te": 16, "tf": 16, "pad_to": 4},
            PlanEntry(method="pallas", tm=32, te=16, tf=16, pad_to=4)),
        3: ({"method": "pallas", "tm": 16, "te": 32, "tf": 32, "pad_to": 8,
             "fuse": True},
            PlanEntry(method="pallas", tm=16, te=32, tf=32, pad_to=8,
                      fuse=True)),
        4: ({"method": "pallas", "tm": 8, "te": 16, "tf": 16, "pad_to": 8,
             "fuse": True, "pipeline": True, "permute": True},
            PlanEntry(method="pallas", tm=8, te=16, tf=16, pad_to=8,
                      fuse=True, pipeline=True, permute=True)),
        5: ({"method": "bsr", "te": 16, "tf": 16, "fuse": True,
             "block_m": 8, "block_n": 128},
            PlanEntry(method="bsr", te=16, tf=16, fuse=True,
                      block_m=8, block_n=128)),
    }
    assert set(fixtures) == set(MIGRATABLE_VERSIONS)
    for ver, (raw, expect) in fixtures.items():
        p = tmp_path / f"v{ver}.json"
        p.write_text(json.dumps({"version": ver, "entries": {"k": raw}}))
        cache = PlanCache(str(p))
        assert cache.get("k") == expect
        assert cache.get("k").value_dtype == "float32"
        if ver < 5:
            assert cache.get("k").block_m is None
        out = tmp_path / f"v{ver}-migrated.json"
        cache.save(str(out))
        doc = json.loads(out.read_text())
        assert doc["version"] == CACHE_VERSION == 6
        assert doc["entries"]["k"]["value_dtype"] == "float32"
        assert PlanCache(str(out)).entries == cache.entries


def test_stale_v4_bsr_plan_falls_back_to_dense(tmp_path):
    """A pre-v5 plan entry claiming method="bsr" migrates with no block
    shape; the engine must treat it as stale and execute the dense path —
    numerically identical to method="dense" — instead of crashing."""
    import json

    path = tmp_path / "stale.json"
    path.write_text(json.dumps({
        "version": 4,
        "entries": {"k": {"method": "bsr", "te": 8, "tf": 8,
                          "est_s": 1e-5, "source": "roofline"}}}))
    pe = PlanCache(str(path)).get("k")
    assert pe.method == "bsr" and pe.block_m is None and pe.block_n is None
    net = [cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu()]
    rng = np.random.default_rng(29)
    params = cnn.init_cnn(net, 3, rng, 10)
    x = jnp.asarray(rng.standard_normal((1, 3, 10, 10)).astype(np.float32))
    apply_plan_to_params(params, {"c1": pe})
    assert "bcsr_auto" not in params["c1"]  # nothing to build from a stale entry
    y_auto = cnn.cnn_forward(net, params, x, method="auto", plan={"c1": pe})
    y_dense = cnn.cnn_forward(net, params, x, method="dense")
    np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_dense))


def test_wall_mode_measures_and_picks(tmp_path):
    # Tiny single-layer net: wall mode must run and record a measured source.
    net = [cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.7), cnn.Relu()]
    rng = np.random.default_rng(0)
    params = cnn.init_cnn(net, 4, rng, 8)
    plan = plan_network(net, 4, 8, batch=1, mode="wall", cache=PlanCache(),
                        params=params, iters=1)
    assert plan["c1"].source == "measured"
    assert plan["c1"].method in ("dense", "lowered", "csr-direct")


# ---------------------------------------------------------------------------
# method="auto" numerical equivalence (paper layer slices, interpret mode)
# ---------------------------------------------------------------------------

def _slice(net_name, n_sparse=2, image=12):
    full = cnn.NETWORKS[net_name]()
    convs = [l for l, _ in cnn.conv_layer_shapes(full, 3, 224)]
    picked = ([next(l for l in convs if l.sparsity == 0)]
              + [l for l in convs if l.sparsity > 0][:n_sparse])
    out = []
    for l in picked:
        out.append(dataclasses.replace(
            l, out_c=max(8, min(32, l.out_c // 8)), stride=1))
        out.append(cnn.Relu())
    return out, image


@pytest.mark.parametrize("net_name", ["alexnet", "resnet50"])
def test_auto_matches_dense_on_slice(net_name):
    net, image = _slice(net_name)
    rng = np.random.default_rng(3)
    params = cnn.init_cnn(net, 3, rng, image)
    x = jnp.asarray(rng.standard_normal((1, 3, image, image)).astype(np.float32))
    plan = plan_network(net, 3, image, batch=1, mode="roofline",
                        cache=PlanCache())
    apply_plan_to_params(params, plan)
    y_auto = cnn.cnn_forward(net, params, x, method="auto", plan=plan)
    y_dense = cnn.cnn_forward(net, params, x, method="dense")
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_auto_without_plan_self_tunes():
    net = [cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
           cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75)]
    rng = np.random.default_rng(5)
    params = cnn.init_cnn(net, 3, rng, 8)
    x = jnp.asarray(rng.standard_normal((1, 3, 8, 8)).astype(np.float32))
    y_auto = cnn.cnn_forward(net, params, x, method="auto")
    y_dense = cnn.cnn_forward(net, params, x, method="dense")
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_auto_executes_pipelined_permuted_plan():
    """A plan entry pinning the full v4 schedule — pallas, fused epilogue,
    double-buffered staging, nnz-balanced bank — must execute through
    method="auto" and match the dense oracle (interpret mode)."""
    net = [cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
           cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu()]
    rng = np.random.default_rng(17)
    params = cnn.init_cnn(net, 3, rng, 10)
    x = jnp.asarray(rng.standard_normal((1, 3, 10, 10)).astype(np.float32))
    plan = {"c0": PlanEntry(method="dense"),
            "c1": PlanEntry(method="pallas", tm=4, te=6, tf=6, pad_to=8,
                            fuse=True, pipeline=True, permute=True)}
    apply_plan_to_params(params, plan)
    assert params["c1"]["ell_auto"].perm is not None  # balanced bank built
    y_auto = cnn.cnn_forward(net, params, x, method="auto", plan=plan)
    y_dense = cnn.cnn_forward(net, params, x, method="dense")
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_auto_balances_in_trace_without_apply_plan():
    """The same permuted plan executed *without* apply_plan_to_params: the
    engine must balance the natural-order bank in-trace (pure gathers)."""
    net = [cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu()]
    rng = np.random.default_rng(19)
    params = cnn.init_cnn(net, 3, rng, 10)
    x = jnp.asarray(rng.standard_normal((1, 3, 10, 10)).astype(np.float32))
    plan = {"c1": PlanEntry(method="pallas", tm=4, te=6, tf=6, pad_to=8,
                            fuse=True, pipeline=True, permute=True)}
    y_auto = cnn.cnn_forward(net, params, x, method="auto", plan=plan)
    y_dense = cnn.cnn_forward(net, params, x, method="dense")
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_apply_plan_rebuilds_formats():
    net = [cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.8)]
    rng = np.random.default_rng(7)
    params = cnn.init_cnn(net, 4, rng, 8)
    plan = {"c1": PlanEntry(method="csr-direct", pad_to=4)}
    apply_plan_to_params(params, plan)
    assert params["c1"]["ell_auto"].k % 4 == 0
    plan2 = {"c1": PlanEntry(method="lowered", pad_to=16)}
    apply_plan_to_params(params, plan2)
    assert params["c1"]["ell2d_auto"].k % 16 == 0


# ---------------------------------------------------------------------------
# quantised value-dtype axis (v6): opt-in enumeration, roofline credit,
# backend capability filtering, cache round-trip
# ---------------------------------------------------------------------------

def test_candidate_space_default_is_f32_only():
    """Narrow value storage is lossy, so the default space must stay
    float32 — quantised candidates appear only on explicit opt-in, and
    then for the Pallas paths alone (dense/lowered/csr-direct have no
    narrow bank to stream)."""
    from repro.tuning.space import VALUE_DTYPES

    g = _geom()
    assert {c.value_dtype for c in enumerate_candidates(g)} == {"float32"}
    cands = enumerate_candidates(g, value_dtypes=VALUE_DTYPES)
    for method in ("pallas", "bsr"):
        assert ({c.value_dtype for c in cands if c.method == method}
                == set(VALUE_DTYPES))
    assert all(c.value_dtype == "float32" for c in cands
               if c.method not in ("pallas", "bsr"))
    _assert_pallas_fits(g, cands)


def test_allowed_value_dtypes_backend_policy():
    """fp8 needs TPU hardware casts; int8 and f32 run everywhere.  This is
    the single capability table the planner and the static verifier share,
    so they can never disagree about a plan's executability."""
    from repro.tuning.space import VALUE_DTYPES, allowed_value_dtypes

    assert allowed_value_dtypes("tpu") == VALUE_DTYPES
    for backend in ("cpu", "gpu"):
        got = allowed_value_dtypes(backend)
        assert "float8_e4m3fn" not in got
        assert "float32" in got and "int8" in got


def test_roofline_credits_quantised_value_stream():
    """Same schedule, narrower values: the roofline charges the int8
    variant strictly fewer HBM bytes than its f32 twin (smaller value
    stream + one f32 scale row) for both Pallas paths, and on a
    weight-bound geometry — a big bank over a tiny feature map — the time
    bound drops too."""
    from repro.tuning.measure import candidate_cost

    g = _geom(m=256, c=256, h=28, w=28, sparsity=0.9)
    pallas = Candidate("pallas", tm=8, pad_to=8)
    bsr = Candidate("bsr", block_m=8, block_n=128)
    for cand in (pallas, bsr):
        q = dataclasses.replace(cand, value_dtype="int8")
        assert (candidate_cost(g, q)["hbm_bytes"]
                < candidate_cost(g, cand)["hbm_bytes"])
    g_wb = _geom(m=512, c=512, h=7, w=7, sparsity=0.95, batch=1)
    for cand in (pallas, bsr):
        q = dataclasses.replace(cand, value_dtype="int8")
        assert roofline_estimate(g_wb, q) < roofline_estimate(g_wb, cand)


def test_plan_layer_quantize_opt_in():
    """plan_layer never pins a narrow dtype unless asked; with
    quantize=True the roofline prefers the smaller value stream on a
    memory-bound layer, and an off-TPU backend can never pin fp8."""
    from repro.tuning import plan_layer

    g = _geom(m=256, c=256, h=28, w=28, sparsity=0.9)
    assert plan_layer(g, mode="roofline").value_dtype == "float32"
    pe = plan_layer(g, mode="roofline", quantize=True)
    assert pe.method in ("pallas", "bsr")
    assert pe.value_dtype == "int8"   # cpu backend: fp8 filtered out
    pe_tpu = plan_layer(g, mode="roofline", backend="tpu", quantize=True)
    assert pe_tpu.value_dtype in ("int8", "float8_e4m3fn")


def test_plan_entry_value_dtype_roundtrip():
    """value_dtype survives the cache dict round-trip, and absent keys
    (v1-v5 documents) default to the f32 value stream."""
    pe = PlanEntry(method="bsr", te=16, tf=16, block_m=8, block_n=128,
                   value_dtype="int8", est_s=1e-5, source="roofline")
    d = pe.to_dict()
    assert d["value_dtype"] == "int8"
    assert PlanEntry.from_dict(d) == pe
    legacy = {k: v for k, v in d.items() if k != "value_dtype"}
    assert PlanEntry.from_dict(legacy).value_dtype == "float32"
