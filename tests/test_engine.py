"""Compile-once graph engine: lowering structure, bit-for-bit equivalence
with the pre-engine spec walkers, and bind-time FC parameter creation.

The "legacy" reference implementations below are verbatim copies of the
historical ``models/cnn.py`` walkers (init_cnn.walk / cnn_forward.walk) —
the engine must reproduce their outputs *bit-for-bit* for the
dense/lowered/csr-direct methods on AlexNet/GoogLeNet/ResNet-50 smoke
shapes, per the refactor's acceptance contract.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.direct_conv import dense_conv, direct_sparse_conv
from repro.core.lowering import lowered_sparse_conv
from repro.core.pruning import magnitude_prune
from repro.core.sparse_format import ell_from_dense, ell_from_dense_conv
from repro.engine import (ConcatOp, ConvOp, FCOp, PoolOp,
                          ReluOp, ResidualAddOp, lower)
from repro.models import cnn

SMOKE = [("alexnet", 67), ("googlenet", 48), ("resnet50", 48)]


# ---------------------------------------------------------------------------
# legacy reference: the pre-engine walkers, verbatim
# ---------------------------------------------------------------------------

def legacy_init_cnn(net, in_c, rng, image=224):
    params = {}

    def walk(layers, c):
        for l in layers:
            if isinstance(l, cnn.Conv):
                w = (rng.standard_normal((l.out_c, c, l.k, l.k))
                     .astype(np.float32) * (2.0 / (c * l.k * l.k)) ** 0.5)
                if l.sparsity > 0:
                    w = np.asarray(magnitude_prune(jnp.asarray(w), l.sparsity))
                entry = {"w": jnp.asarray(w),
                         "b": jnp.zeros((l.out_c,), jnp.float32)}
                if l.sparsity > 0:
                    entry["ell"] = ell_from_dense_conv(w)
                    entry["ell2d"] = ell_from_dense(w.reshape(l.out_c, -1))
                params[l.name] = entry
                c = l.out_c
            elif isinstance(l, cnn.Concat):
                c = sum(walk(br, c) for br in l.branches)
            elif isinstance(l, cnn.Residual):
                cb = walk(l.body, c)
                if l.proj is not None:
                    walk((l.proj,), c)
                c = cb
        return c

    walk(net, in_c)
    params["_fc_rng"] = rng.integers(0, 2**31)
    return params


def _legacy_conv_apply(l, entry, x, method):
    if l.sparsity == 0 or method == "dense":
        y = dense_conv(x, entry["w"], stride=l.stride, padding=l.pad)
    elif method == "lowered":
        y = lowered_sparse_conv(x, entry["ell2d"], l.k, l.k,
                                stride=l.stride, padding=l.pad)
    elif method == "csr-direct":
        y = direct_sparse_conv(x, entry["ell"], stride=l.stride, padding=l.pad)
    else:
        raise ValueError(method)
    return y + entry["b"][None, :, None, None]


def _legacy_pool(l, x):
    if l.kind == "gap":
        return x.mean(axis=(2, 3), keepdims=True)
    init = -jnp.inf if l.kind == "max" else 0.0
    op = jax.lax.max if l.kind == "max" else jax.lax.add
    y = jax.lax.reduce_window(
        x, init, op, (1, 1, l.k, l.k), (1, 1, l.stride, l.stride),
        ((0, 0), (0, 0), (l.pad, l.pad), (l.pad, l.pad)))
    if l.kind == "avg":
        y = y / (l.k * l.k)
    return y


def legacy_cnn_forward(net, params, x, method="dense"):
    fc_rng = np.random.default_rng(int(params["_fc_rng"]))

    def walk(layers, x):
        for l in layers:
            if isinstance(l, cnn.Conv):
                x = _legacy_conv_apply(l, params[l.name], x, method)
            elif isinstance(l, cnn.Relu):
                x = jax.nn.relu(x)
            elif isinstance(l, cnn.Pool):
                x = _legacy_pool(l, x)
            elif isinstance(l, cnn.Concat):
                x = jnp.concatenate([walk(br, x) for br in l.branches], axis=1)
            elif isinstance(l, cnn.Residual):
                y = walk(l.body, x)
                sc = (_legacy_conv_apply(l.proj, params[l.proj.name], x, method)
                      if l.proj is not None else x)
                x = y + sc
            elif isinstance(l, cnn.FC):
                flat = x.reshape(x.shape[0], -1)
                key = f"{l.name}:{flat.shape[1]}"
                if key not in params:
                    params[key] = (
                        fc_rng.standard_normal((flat.shape[1], l.out_f))
                        .astype(np.float32) * (1.0 / flat.shape[1]) ** 0.5)
                x = flat @ params[key]
        return x

    return walk(net, x)


# ---------------------------------------------------------------------------
# lowering structure
# ---------------------------------------------------------------------------

def test_lowering_is_flat_and_fused():
    net = cnn.NETWORKS["alexnet"]()
    prog = lower(net, (3, 67, 67))
    kinds = {type(op) for op in prog.ops}
    assert kinds <= {ConvOp, PoolOp, FCOp, ReluOp, ConcatOp, ResidualAddOp}
    convs = prog.conv_ops
    assert len(convs) == 5
    # every AlexNet conv is followed by a ReLU -> fused at lowering time
    assert all(op.fuse_relu for op in convs)
    # the conv+ReLU pairs collapsed: only the two post-FC ReLUs remain
    assert sum(isinstance(op, ReluOp) for op in prog.ops) == 2
    # geometry statically resolved: conv1 stride-4 stem at 67px -> 15x15 out
    assert (convs[0].e, convs[0].f) == (15, 15)
    # FC fan-in resolved statically (no lazy flattened-dim discovery)
    assert prog.fc_ops[0].in_f == 256 * 1 * 1


def test_lowering_fuses_bottleneck_tail():
    net = cnn.NETWORKS["resnet50"]()
    prog = lower(net, (3, 64, 64))
    tails = [op for op in prog.conv_ops if op.res is not None]
    # one fused tail per bottleneck (3+4+6+3 = 16 blocks), shortcut + ReLU
    assert len(tails) == 16
    assert all(op.fuse_relu for op in tails)
    assert all(op.name.endswith("1x1b") for op in tails)
    # no standalone residual-add ops remain
    assert not any(isinstance(op, ResidualAddOp) for op in prog.ops)
    # the shortcut value is defined before the tail conv consumes it
    for tail in tails:
        defined = {0}
        for op in prog.ops:
            if op is tail:
                assert tail.res in defined
                break
            defined.add(op.out)


def test_conv_table_matches_legacy_walk_order():
    """conv_table drives init: it must visit convs in the historical order
    (Residual: body then proj) so RNG draws line up bit-for-bit."""
    net = cnn.NETWORKS["resnet50"]()
    prog = lower(net, (3, 64, 64))
    names = [l.name for l, _ in prog.conv_table]
    i_body = names.index("res2a/1x1b")
    i_proj = names.index("res2a/proj")
    assert i_body < i_proj  # body before proj, as the legacy walker did
    legacy = legacy_init_cnn(net, 3, np.random.default_rng(0), 64)
    assert [n for n in names] == [k for k in legacy if k != "_fc_rng"]


def test_shape_table_delegates_to_lowering():
    net = cnn.NETWORKS["googlenet"]()
    shapes = cnn.conv_layer_shapes(net, 3, 96)
    prog = lower(net, (3, 96, 96))
    assert shapes == list(prog.conv_table)
    # spot-check a known geometry: conv2 sees the pooled 24x24 map
    by_name = {l.name: s for l, s in shapes}
    assert by_name["conv2"] == (64, 24, 24)


# ---------------------------------------------------------------------------
# bit-for-bit equivalence with the pre-engine implementation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net_name,image", SMOKE)
def test_init_matches_legacy_bitwise(net_name, image):
    net = cnn.NETWORKS[net_name]()
    new = cnn.init_cnn(net, 3, np.random.default_rng(0), image)
    old = legacy_init_cnn(net, 3, np.random.default_rng(0), image)
    assert int(new["_fc_rng"]) == int(old["_fc_rng"])
    assert set(old) == set(new)
    for k in old:
        if k == "_fc_rng":
            continue
        np.testing.assert_array_equal(np.asarray(old[k]["w"]),
                                      np.asarray(new[k]["w"]))


@pytest.mark.parametrize("net_name,image", SMOKE)
@pytest.mark.parametrize("method", ["dense", "lowered", "csr-direct"])
def test_forward_matches_legacy_bitwise(net_name, image, method):
    net = cnn.NETWORKS[net_name]()
    rng = np.random.default_rng(7)
    params = cnn.init_cnn(net, 3, rng, image)
    x = jnp.asarray(np.random.default_rng(11)
                    .standard_normal((1, 3, image, image)).astype(np.float32))
    old = np.asarray(jax.jit(functools.partial(
        legacy_cnn_forward, net, params, method=method))(x))
    new = np.asarray(cnn.cnn_forward(net, params, x, method))
    np.testing.assert_array_equal(old, new)


# ---------------------------------------------------------------------------
# FC params: created at bind, never inside a trace (satellite regression)
# ---------------------------------------------------------------------------

def _fc_net():
    return [cnn.Conv("c0", 4, 3, 1, 1, sparsity=0.0), cnn.Relu(),
            cnn.Pool("max", 2, 2), cnn.FC("fc1", 16), cnn.Relu(),
            cnn.FC("fc2", 8)]


def test_fc_weights_created_at_bind_not_in_params():
    """The engine never mutates params during a trace: FC weights live in
    the engine bind, keyed on (name, static fan-in)."""
    net = _fc_net()
    params = cnn.init_cnn(net, 3, np.random.default_rng(0), 8)
    keys_before = set(params)
    x = jnp.ones((2, 3, 8, 8), jnp.float32)
    y = cnn.cnn_forward(net, params, x)
    assert y.shape == (2, 8)
    assert set(params) == keys_before  # no lazily-injected FC entries
    eng = cnn.engine_for(net, params, (3, 8, 8))
    assert ("fc1", 4 * 4 * 4) in eng.fc_weights


def test_fc_traces_at_two_image_sizes_do_not_collide():
    """Two traces at different image sizes must not collide: each size's
    outputs are deterministic regardless of which size traced first.  (The
    historical lazy creation was order-dependent — whichever size ran first
    pinned the downstream FC draws for every later size.)"""
    net = _fc_net()
    params = cnn.init_cnn(net, 3, np.random.default_rng(0), 8)
    xa = jnp.ones((1, 3, 8, 8), jnp.float32)
    xb = jnp.ones((1, 3, 12, 12), jnp.float32)
    ya_first = np.asarray(cnn.cnn_forward(net, params, xa))
    yb_second = np.asarray(cnn.cnn_forward(net, params, xb))
    # fresh params, reversed call order: outputs must be unchanged
    params2 = cnn.init_cnn(net, 3, np.random.default_rng(0), 8)
    yb_first = np.asarray(cnn.cnn_forward(net, params2, xb))
    ya_second = np.asarray(cnn.cnn_forward(net, params2, xa))
    np.testing.assert_array_equal(ya_first, ya_second)
    np.testing.assert_array_equal(yb_second, yb_first)
    ea = cnn.engine_for(net, params, (3, 8, 8))
    eb = cnn.engine_for(net, params, (3, 12, 12))
    (ka,) = [k for k in ea.fc_weights if k[0] == "fc1"]
    (kb,) = [k for k in eb.fc_weights if k[0] == "fc1"]
    assert ka != kb  # different fan-ins -> different keys, no collision
    # and binds are reproducible: same params identity, same weights
    np.testing.assert_array_equal(
        ea.fc_weights[ka],
        cnn.engine_for(net, params2, (3, 8, 8)).fc_weights[ka])


# ---------------------------------------------------------------------------
# engine execution: cached jit + fused pallas agreement
# ---------------------------------------------------------------------------

def test_engine_caches_one_jit_per_method_and_shape():
    net = _fc_net()
    params = cnn.init_cnn(net, 3, np.random.default_rng(0), 8)
    eng = cnn.engine_for(net, params, (3, 8, 8))
    x = jnp.ones((1, 3, 8, 8), jnp.float32)
    eng(x, "dense")
    eng(x, "dense")
    assert len(eng._fns) == 1
    eng(x, "csr-direct")
    assert len(eng._fns) == 2
    eng(jnp.ones((2, 3, 8, 8), jnp.float32), "dense")
    assert len(eng._fns) == 3
    # repeated cnn_forward calls reuse the memoized engine
    assert cnn.engine_for(net, params, (3, 8, 8)) is eng


def test_params_update_rebinds_engine():
    """Replacing a weight (or apply_plan_to_params adding formats) after a
    forward must bind a fresh engine — not replay a jit that baked the old
    arrays in as constants."""
    net = [cnn.Conv("c0", 4, 3, 1, 1, sparsity=0.0), cnn.Relu()]
    params = cnn.init_cnn(net, 3, np.random.default_rng(0), 8)
    x = jnp.ones((1, 3, 8, 8), jnp.float32)
    y0 = np.asarray(cnn.cnn_forward(net, params, x))
    params["c0"]["w"] = params["c0"]["w"] * 2.0
    y1 = np.asarray(cnn.cnn_forward(net, params, x))
    np.testing.assert_array_equal(y1, 2.0 * y0)


@pytest.mark.parametrize("method", ["pallas", "auto"])
def test_engine_fused_methods_match_dense(method):
    """Fused in-kernel epilogue (bias/ReLU/bottleneck shortcut) agrees with
    the dense oracle end-to-end, including a projection residual block."""
    net = [cnn.Conv("c0", 8, 3, 2, 1, sparsity=0.0), cnn.Relu(),
           cnn.Residual(body=(cnn.Conv("r/1x1a", 8, 1, sparsity=0.7),
                              cnn.Relu(),
                              cnn.Conv("r/1x1b", 16, 1, sparsity=0.7)),
                        proj=cnn.Conv("r/proj", 16, 1, sparsity=0.0)),
           cnn.Relu()]
    rng = np.random.default_rng(3)
    params = cnn.init_cnn(net, 3, rng, 12)
    # non-zero biases so the fused bias add is actually exercised
    for name in ("c0", "r/1x1a", "r/1x1b", "r/proj"):
        m = params[name]["b"].shape[0]
        params[name]["b"] = jnp.asarray(
            rng.standard_normal((m,)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((2, 3, 12, 12)).astype(np.float32))
    ref = np.asarray(cnn.cnn_forward(net, params, x, "dense"))
    out = np.asarray(cnn.cnn_forward(net, params, x, method))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    unfused = np.asarray(cnn.engine_for(net, params, (3, 12, 12))(
        x, "pallas", fuse=False))
    np.testing.assert_allclose(unfused, ref, rtol=1e-5, atol=1e-5)

# ---------------------------------------------------------------------------
# quantised value streams: pinned plans execute narrow banks, stale plans
# fall back loudly
# ---------------------------------------------------------------------------

def _quant_micro():
    import dataclasses

    from repro.tuning import PlanCache, plan_program

    net = [cnn.Conv("c0", 8, 3, 1, 1, sparsity=0.0), cnn.Relu(),
           cnn.Conv("c1", 8, 3, 1, 1, sparsity=0.75), cnn.Relu(),
           cnn.Pool("gap"), cnn.FC("fc", 10)]
    rng = np.random.default_rng(0)
    program = lower(net, (3, 8, 8))
    params = cnn.init_cnn(net, 3, rng, 8)
    x = rng.standard_normal((1, 3, 8, 8)).astype(np.float32)
    plan = plan_program(program, batch=1, mode="roofline", cache=PlanCache())
    qplan = {name: (dataclasses.replace(pe, value_dtype="int8")
                    if pe.method in ("pallas", "bsr") else pe)
             for name, pe in plan.items()}
    assert any(pe.value_dtype == "int8" for pe in qplan.values())
    return program, params, x, plan, qplan


def test_engine_int8_pinned_plan_executes_quantised():
    """An int8-pinned plan over host-quantised banks executes its planned
    kernels — zero fallbacks, the report rows carry the executed narrow
    dtype — and the output agrees with the f32 forward to quantisation
    tolerance."""
    from repro import telemetry
    from repro.engine import CnnEngine
    from repro.tuning import apply_plan_to_params

    program, params, x, plan, qplan = _quant_micro()
    qparams = apply_plan_to_params(params, qplan)
    engine = CnnEngine(program, qparams, qplan, strict=True)
    telemetry.reset()
    try:
        with telemetry.enabled():
            y_q = np.asarray(engine(x, "auto"))
            report = engine.last_report
    finally:
        telemetry.reset()
    assert report is not None and report.fallback_count == 0
    assert any(o.value_dtype == "int8" for o in report.ops)
    y_f = np.asarray(CnnEngine(program, params, None)(x, "dense"))
    rel = np.abs(y_q - y_f).max() / (np.abs(y_f).max() or 1.0)
    assert rel < 0.05, rel


def test_engine_int8_plan_quantises_f32_bank_in_trace():
    """A narrow plan bound over plain f32 banks quantises in-trace — same
    per-channel scales, baked into the jit — so the output is bit-identical
    to the host-side apply_plan_to_params route."""
    from repro.engine import CnnEngine
    from repro.tuning import apply_plan_to_params

    program, params, x, plan, qplan = _quant_micro()
    y_trace = np.asarray(CnnEngine(program, params, qplan)(x, "auto"))
    qparams = apply_plan_to_params(params, qplan)
    y_host = np.asarray(CnnEngine(program, qparams, qplan)(x, "auto"))
    np.testing.assert_array_equal(y_trace, y_host)


def test_engine_value_dtype_mismatch_falls_back_dense():
    """A migrated f32 plan executed against an already-quantised bank must
    NOT silently dequantise: the op falls back to dense with the
    ``value_dtype_mismatch`` reason (and so stays numerically exact)."""
    from repro import telemetry
    from repro.engine import CnnEngine
    from repro.tuning import apply_plan_to_params

    program, params, x, plan, qplan = _quant_micro()
    qparams = apply_plan_to_params(params, qplan)   # int8 banks...
    engine = CnnEngine(program, qparams, plan)      # ...but the f32 plan
    telemetry.reset()
    try:
        with telemetry.enabled():
            y = np.asarray(engine(x, "auto"))
            report = engine.last_report
    finally:
        telemetry.reset()
    assert report is not None and report.fallback_count > 0
    reasons = {o.fallback_reason for o in report.fallback_ops}
    assert reasons == {"value_dtype_mismatch"}
    # every mismatched op executed the exact dense path
    assert all(o.value_dtype == "float32" for o in report.ops)
    y_dense = np.asarray(CnnEngine(program, params, None)(x, "dense"))
    np.testing.assert_allclose(y, y_dense, rtol=1e-5, atol=1e-6)
