"""Pallas BCSR MXU matmul kernel: interpret-mode sweeps vs the jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bcsr_from_dense, block_prune
from repro.kernels.bsr_matmul import ops
from repro.kernels.bsr_matmul.ops import bsr_matmul, choose_tb
from repro.kernels.bsr_matmul.ref import bsr_matmul_ref

pytestmark = pytest.mark.pallas

CASES = [
    # (B, M, N, block, sparsity)
    (8, 64, 64, (16, 16), 0.5),
    (37, 160, 192, (32, 64), 0.6),     # unaligned batch
    (16, 128, 128, (128, 128), 0.0),   # single dense tile
    (64, 96, 256, (32, 32), 0.9),      # very sparse
    (5, 72, 80, (8, 16), 0.4),         # ragged vs block
]


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_oracle(case):
    b, m, n, block, sp = case
    rng = np.random.default_rng(abs(hash(case)) % 2**31)
    w = rng.standard_normal((m, n)).astype(np.float32)
    if sp > 0:
        w = np.asarray(block_prune(jnp.asarray(w), sp, block))
    bc = bcsr_from_dense(w, block)
    x = jnp.asarray(rng.standard_normal((b, n)).astype(np.float32))
    got = bsr_matmul(x, bc, interpret=True)
    ref = bsr_matmul_ref(x, bc)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_kernel_dtypes(dtype, tol):
    rng = np.random.default_rng(3)
    w = np.asarray(block_prune(
        jnp.asarray(rng.standard_normal((64, 96)).astype(np.float32)),
        0.5, (16, 16)))
    bc = bcsr_from_dense(w.astype(dtype), (16, 16))
    x = jnp.asarray(rng.standard_normal((12, 96)), dtype=dtype)
    got = bsr_matmul(x, bc, interpret=True)
    ref = bsr_matmul_ref(x.astype(jnp.float32),
                         bcsr_from_dense(w, (16, 16)))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_leading_batch_dims():
    rng = np.random.default_rng(5)
    w = np.asarray(block_prune(
        jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32)),
        0.5, (16, 16)))
    bc = bcsr_from_dense(w, (16, 16))
    x = jnp.asarray(rng.standard_normal((2, 3, 64)).astype(np.float32))
    got = bsr_matmul(x, bc, interpret=True)
    assert got.shape == (2, 3, 32)
    ref = bsr_matmul_ref(x.reshape(-1, 64), bc).reshape(2, 3, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_fully_pruned_block_rows():
    """A block-row with zero surviving tiles must yield exact zeros."""
    w = np.zeros((32, 64), np.float32)
    w[16:, :16] = 1.0  # only the second block-row has content
    bc = bcsr_from_dense(w, (16, 16))
    x = jnp.ones((4, 64), jnp.float32)
    got = np.asarray(bsr_matmul(x, bc, interpret=True))
    np.testing.assert_array_equal(got[:, :16], 0.0)
    np.testing.assert_array_equal(got[:, 16:], 16.0)


def test_choose_tb_divides():
    tb = choose_tb(1024, 128, 128, 2)
    assert 1024 % tb == 0


# ---------------------------------------------------------------------------
# ops edge cases: batch padding, tb override, dtype policy, VMEM fallback
# ---------------------------------------------------------------------------

def _blocked(rng, m, n, block, sp=0.5):
    w = np.asarray(block_prune(
        jnp.asarray(rng.standard_normal((m, n)).astype(np.float32)), sp, block))
    return w, bcsr_from_dense(w, block)


def test_non_dividing_batch_pads_and_slices(monkeypatch):
    """An explicit tb that does not divide B must zero-pad the batch for
    the kernel and slice the padding rows back off — values identical to
    the unpadded oracle."""
    rng = np.random.default_rng(7)
    w, bc = _blocked(rng, 32, 64, (16, 16))
    x = jnp.asarray(rng.standard_normal((10, 64)).astype(np.float32))
    launches = []
    real = ops.bsr_matmul_pallas
    monkeypatch.setattr(
        ops, "bsr_matmul_pallas",
        lambda *a, **kw: launches.append((a[0].shape, kw)) or real(*a, **kw))
    got = bsr_matmul(x, bc, tb=8, interpret=True)
    assert got.shape == (10, 32)
    # the kernel saw a padded batch: 10 -> 16 rows of tb=8
    assert launches and launches[0][0] == (16, 64)
    ref = bsr_matmul_ref(x, bc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_explicit_tb_override_honored(monkeypatch):
    """A caller-pinned tb must reach the kernel verbatim (the autotuner's
    knob), not be re-derived by choose_tb."""
    rng = np.random.default_rng(9)
    w, bc = _blocked(rng, 32, 64, (16, 16))
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    launches = []
    real = ops.bsr_matmul_pallas
    monkeypatch.setattr(
        ops, "bsr_matmul_pallas",
        lambda *a, **kw: launches.append(kw) or real(*a, **kw))
    got = bsr_matmul(x, bc, tb=16, interpret=True)
    assert launches and launches[0]["tb"] == 16
    ref = bsr_matmul_ref(x, bc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bf16_in_f32_accumulate_policy():
    """Dtype policy: bf16 inputs/weights, f32 in-kernel accumulation, cast
    back to the input dtype on exit.  The raw kernel output is f32; the
    wrapper's result is bf16 and within bf16 rounding of the f32 oracle."""
    rng = np.random.default_rng(11)
    w, bc32 = _blocked(rng, 32, 64, (16, 16))
    import dataclasses
    bc16 = dataclasses.replace(bc32, blocks=bc32.blocks.astype(jnp.bfloat16))
    x16 = jnp.asarray(rng.standard_normal((16, 64)), dtype=jnp.bfloat16)
    got = bsr_matmul(x16, bc16, interpret=True)
    assert got.dtype == jnp.bfloat16
    raw = ops.bsr_matmul_pallas(x16, bc16.blocks, bc16.blockcol, bc16.nblocks,
                                tb=16, interpret=True)
    assert raw.dtype == jnp.float32
    ref = bsr_matmul_ref(x16.astype(jnp.float32), bc32)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


def test_choose_tb_vmem_fallback_is_8():
    """When even the smallest dividing tile busts the VMEM budget, choose_tb
    pins the fallback batch tile to 8 (the MXU's minimum useful sublane
    count) instead of erroring or returning an over-budget tile."""
    # bm*bn*itemsize alone exceeds the 12 MiB budget -> every rung fails.
    assert choose_tb(1024, 4096, 4096, 4) == 8
    # and a budget-respecting case still prefers the largest dividing rung
    assert choose_tb(1024, 128, 128, 4) == 1024
