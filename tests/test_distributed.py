"""Sharding rules, spec resolution, and a real multi-device train step
(8 forced host devices in a subprocess, since device count locks at init)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro import configs as cfgs
from repro.models import transformer as T


def test_resolve_rules():
    mesh = jax.make_mesh((1,), ("data",))
    with shd.use_rules({"fsdp": "data", "tp": "model",
                        "dp": ("data",), "sp": "model"}, mesh):
        assert shd.resolve(P("fsdp", "tp")) == P("data", "model")
        assert shd.resolve(P("dp", None)) == P(("data",), None)
        assert shd.resolve(P(None)) == P(None)
        assert shd.resolve(P("unknown")) == P(None)


def test_constrain_noop_outside_mesh():
    x = jax.numpy.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(shd.constrain(x, "dp", None)),
                                  np.asarray(x))


@pytest.mark.parametrize("arch", ["yi-9b", "olmoe-1b-7b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b"])
def test_param_specs_match_param_tree(arch):
    """Spec pytree must be congruent with the param pytree and rank-correct."""
    cfg = cfgs.get_config(arch, smoke=True)
    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = T.param_specs(cfg, tp=2)
    jax.tree.map(lambda p, s: None, params, specs,
                 is_leaf=lambda x: isinstance(x, P))  # structure congruence
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(tuple(s)) <= p.ndim, (p.shape, s)


def test_cache_specs_match_cache_tree():
    cfg = cfgs.get_config("jamba-1.5-large-398b", smoke=True)
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 32))
    specs = T.cache_specs(cfg, tp=2)
    jax.tree.map(lambda c, s: None, cache, specs,
                 is_leaf=lambda x: isinstance(x, P))


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs as cfgs
    from repro.distributed import sharding as shd
    from repro.launch.steps import init_state, make_train_step, state_shardings
    from repro.launch.mesh import make_mesh

    cfg = cfgs.get_config("{arch}", smoke=True)
    mesh = make_mesh((4, 2), ("data", "model"))
    with mesh:
        with shd.use_rules(shd.default_rules(mesh), mesh):
            from repro.optim import AdamWConfig
            opt_cfg = AdamWConfig(lr=1e-3)
            state_ns = state_shardings(cfg, mesh, 2)
            step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=10),
                           in_shardings=(state_ns, None),
                           out_shardings=(state_ns, None), donate_argnums=(0,))
            state = jax.device_put(
                init_state(cfg, opt_cfg, jax.random.PRNGKey(0)), state_ns)
            key = jax.random.PRNGKey(1)
            toks = jax.random.randint(key, (8, 32), 0, cfg.vocab, jnp.int32)
            batch = {{"tokens": toks, "labels": jnp.roll(toks, -1, 1)}}
            if cfg.family in ("vlm", "encoder"):
                batch = {{"embeds": jax.random.normal(
                    key, (8, 32, cfg.d_model), jnp.bfloat16),
                    "labels": batch["labels"]}}
            l0 = None
            for _ in range(3):
                state, m = step(state, batch)
                loss = float(m["loss"])
                assert np.isfinite(loss), loss
                l0 = loss if l0 is None else l0
            print("MULTIDEV_OK", l0, loss)
""")


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "olmoe-1b-7b"])
def test_train_step_on_8_devices(arch):
    """Real data+tensor parallel train step on 8 forced host devices."""
    r = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert "MULTIDEV_OK" in r.stdout, r.stderr[-2000:]
