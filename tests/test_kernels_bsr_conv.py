"""BCSR MXU conv kernel: interpret-mode parity grids vs the dense oracle,
the blocked structural mirror (bit-identity), and the ELL Pallas path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bcsr_conv_from_dense, block_prune_conv,
                        ell_from_dense_conv, magnitude_prune)
from repro.core.direct_conv import direct_sparse_conv, out_spatial
from repro.kernels.bsr_conv import ops
from repro.kernels.bsr_conv.ops import (bsr_conv, bsr_smem_fits,
                                        bsr_tile_candidates, bsr_tiling_fits)
from repro.kernels.bsr_conv.ref import (bsr_conv_blocked_ref, bsr_conv_ref)
from repro.kernels.sparse_conv.ops import sparse_conv

pytestmark = pytest.mark.pallas


def _case(seed, n, c, h, w, m, r, sp, block, *, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, c, h, w)), dtype=dtype)
    wt = np.asarray(block_prune_conv(
        jnp.asarray(rng.standard_normal((m, c, r, r)).astype(np.float32)),
        sp, block))
    return rng, x, wt


# ---------------------------------------------------------------------------
# parity grid: stride x padding x residual x bf16 x edge tiles x block sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1])
@pytest.mark.parametrize("residual", [False, True])
@pytest.mark.parametrize("block", [(4, 8), (8, 16)])
def test_bsr_parity_grid(stride, pad, residual, block):
    """The full grid with edge tiles (te/tf deliberately not dividing E/F)
    and a non-dividing M (channel padding path), against the dense oracle
    — and bit-identical to the blocked structural mirror on the untiled
    schedule."""
    n, c, h, w, m, r = 2, 4, 13, 11, 12, 3
    seed = 5000 + 1000 * stride + 100 * pad + 10 * residual + block[0]
    rng, x, wt = _case(seed, n, c, h, w, m, r, 0.6, block)
    bc = bcsr_conv_from_dense(wt, block=block)
    assert bc.gbm * block[0] >= m
    bias = jnp.asarray(rng.standard_normal((m,)).astype(np.float32))
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = (jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32))
           if residual else None)
    te, tf = max(1, (e + 1) // 2), max(1, f // 2 + 1)   # non-dividing tiles
    got = bsr_conv(x, bc, stride=stride, padding=pad, te=te, tf=tf,
                   bias=bias, fuse_relu=True, residual=res, interpret=True)
    ref = bsr_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    ref = jax.nn.relu(ref + bias[None, :, None, None]
                      + (res.astype(jnp.float32) if res is not None else 0.0))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-5, atol=1e-5)
    # Bit-identity anchor: the untiled kernel is the exact op sequence of
    # the blocked mirror (same patch gathers, same per-KB dot_general
    # accumulation order, same f32 epilogue).
    got_untiled = bsr_conv(x, bc, stride=stride, padding=pad,
                           bias=bias, fuse_relu=True, residual=res,
                           interpret=True)
    mirror = bsr_conv_blocked_ref(x, bc, stride=stride, padding=pad,
                                  bias=bias, fuse_relu=True, residual=res)
    np.testing.assert_array_equal(np.asarray(got_untiled, np.float32),
                                  np.asarray(mirror, np.float32))


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("block", [(4, 8), (8, 32)])
def test_bsr_parity_bf16(stride, block):
    """bf16 inputs/weights with f32 accumulation: tolerance is the bf16
    rounding of the conv itself (the contraction is f32)."""
    n, c, h, w, m, r, pad = 1, 4, 12, 12, 8, 3, 1
    rng, x, wt = _case(7000 + stride + block[1], n, c, h, w, m, r, 0.6,
                       block, dtype=jnp.bfloat16)
    bc = bcsr_conv_from_dense(wt.astype(np.float32), block=block)
    bc = dataclasses.replace(bc, blocks=bc.blocks.astype(jnp.bfloat16))
    got = bsr_conv(x, bc, stride=stride, padding=pad, interpret=True)
    assert got.dtype == jnp.bfloat16
    ref = bsr_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("stride", [1, 2])
def test_bsr_matches_ell_pallas_and_direct(stride):
    """Cross-method agreement on one geometry: the BCSR MXU path, the ELL
    Pallas path, and the pure-JAX direct path all compute the same conv."""
    n, c, h, w, m, r, pad = 2, 4, 12, 10, 8, 3, 1
    rng, x, wt = _case(7100 + stride, n, c, h, w, m, r, 0.5, (4, 8))
    bc = bcsr_conv_from_dense(wt, block=(4, 8))
    ell = ell_from_dense_conv(wt)
    y_bsr = bsr_conv(x, bc, stride=stride, padding=pad, interpret=True)
    y_ell = sparse_conv(x, ell, stride=stride, padding=pad, interpret=True)
    y_dir = direct_sparse_conv(x, ell, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(y_bsr), np.asarray(y_ell),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_bsr), np.asarray(y_dir),
                               rtol=2e-5, atol=2e-5)


def test_bsr_unstructured_weights_still_correct():
    """Magnitude-pruned (unstructured) weights keep nearly every tile but
    must stay exactly correct — block sparsity is a performance transform."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 3, 10, 10)).astype(np.float32))
    wt = np.asarray(magnitude_prune(
        jnp.asarray(rng.standard_normal((8, 3, 3, 3)).astype(np.float32)), 0.7))
    bc = bcsr_conv_from_dense(wt, block=(4, 8))
    got = bsr_conv(x, bc, padding=1, interpret=True)
    ref = bsr_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fallbacks + feasibility
# ---------------------------------------------------------------------------

def test_bsr_vmem_infeasible_falls_back(monkeypatch):
    """When no (te, tf) tiling fits VMEM, bsr_conv must fall back to the
    dense-reconstruction path — with the epilogue still applied — instead
    of launching the kernel."""
    rng, x, wt = _case(13, 1, 4, 10, 10, 8, 3, 0.5, (4, 8))
    bc = bcsr_conv_from_dense(wt, block=(4, 8))
    bias = jnp.asarray(rng.standard_normal((8,)).astype(np.float32))
    monkeypatch.setattr(ops, "VMEM_BUDGET", 1024)
    assert bsr_tile_candidates(4, 10, 10, 3, 3, 1, 4, 8) == []

    def _boom(*a, **kw):
        raise AssertionError("over-budget kernel launch")

    monkeypatch.setattr(ops, "bsr_conv_pallas", _boom)
    got = bsr_conv(x, bc, padding=1, bias=bias, fuse_relu=True,
                   interpret=True)
    ref = bsr_conv_ref(x, jnp.asarray(wt), padding=1)
    ref = jax.nn.relu(ref + bias[None, :, None, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_bsr_stale_infeasible_tiling_falls_back(monkeypatch):
    """A fully-specified (te, tf) from a stale tuned plan that busts VMEM
    must fall back, never launch over budget."""
    rng, x, wt = _case(17, 1, 4, 16, 16, 8, 3, 0.5, (4, 8))
    bc = bcsr_conv_from_dense(wt, block=(4, 8))
    # Budget below the untiled working set but above nothing in particular:
    # the pinned (16, 16) tiling must be rejected up front.
    monkeypatch.setattr(ops, "VMEM_BUDGET", 1024)
    assert not bsr_tiling_fits(4, 3, 3, 1, 4, 8, 16, 16)

    def _boom(*a, **kw):
        raise AssertionError("over-budget kernel launch")

    monkeypatch.setattr(ops, "bsr_conv_pallas", _boom)
    got = bsr_conv(x, bc, padding=1, te=16, tf=16, interpret=True)
    ref = bsr_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bsr_smem_infeasible_falls_back(monkeypatch):
    """A block table bigger than SMEM must route to the fallback."""
    rng, x, wt = _case(19, 1, 4, 8, 8, 8, 3, 0.5, (4, 8))
    bc = bcsr_conv_from_dense(wt, block=(4, 8))
    monkeypatch.setattr(ops, "SMEM_BUDGET", 4)
    assert not bsr_smem_fits(bc.gbm, bc.kb)

    def _boom(*a, **kw):
        raise AssertionError("SMEM-infeasible kernel launch")

    monkeypatch.setattr(ops, "bsr_conv_pallas", _boom)
    got = bsr_conv(x, bc, padding=1, interpret=True)
    ref = bsr_conv_ref(x, jnp.asarray(wt), padding=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_bsr_fully_pruned_bank():
    """An all-zero bank keeps one inert tile per block-row (KB clamps to 1)
    and produces exact zeros through the kernel."""
    wt = np.zeros((8, 4, 3, 3), np.float32)
    bc = bcsr_conv_from_dense(wt, block=(4, 8))
    assert bc.kb == 1
    assert int(np.asarray(bc.nblocks).sum()) == 0
    rng = np.random.default_rng(23)
    x = jnp.asarray(rng.standard_normal((1, 4, 8, 8)).astype(np.float32))
    got = bsr_conv(x, bc, padding=1, interpret=True)
    assert got.shape == (1, 8, 8, 8)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_bsr_tiling_fits_accounts_residual_tile():
    """Reserving the fused-residual input tile can rule out tilings that
    fit without it."""
    args = dict(c=8, r=3, s=3, stride=1, bm=8, bn=64, te=64, tf=64)
    x_bytes = 8 * 66 * 66 * 4
    w_bytes = 8 * 64 * 4
    patch = 64 * 64 * 64 * 4
    out = 8 * 64 * 64 * 4
    import repro.kernels.bsr_conv.ops as bops
    orig = bops.VMEM_BUDGET
    try:
        bops.VMEM_BUDGET = x_bytes + w_bytes + patch + out
        assert bsr_tiling_fits(**args)
        assert not bsr_tiling_fits(**args, fuse_res=True)
    finally:
        bops.VMEM_BUDGET = orig

# ---------------------------------------------------------------------------
# quantised value streams: int8 / fp8 banks, scale after the MXU contraction
# ---------------------------------------------------------------------------

from repro.core.sparse_format import QUANT_DTYPES, quantize_values  # noqa: E402


@pytest.mark.parametrize("value_dtype", sorted(QUANT_DTYPES))
@pytest.mark.parametrize("stride", [1, 2])
def test_bsr_quantised_bit_identical_to_blocked_mirror(value_dtype, stride):
    """A quantised bank through the untiled kernel is bit-identical to the
    blocked structural mirror — narrow blocks feed the contraction, the
    per-channel f32 scales multiply each KB-step's contribution, the
    accumulator stays f32 — the tiled schedule agrees to fp tolerance, and
    both land within quantisation tolerance of the dense oracle."""
    n, c, h, w, m, r, pad = 2, 4, 13, 11, 12, 3, 1
    seed = 8800 + 100 * stride + len(value_dtype)
    rng, x, wt = _case(seed, n, c, h, w, m, r, 0.6, (4, 8))
    q = quantize_values(bcsr_conv_from_dense(wt, block=(4, 8)), value_dtype)
    assert q.value_dtype == value_dtype
    bias = jnp.asarray(rng.standard_normal((m,)).astype(np.float32))
    e, f = out_spatial(h, w, r, r, stride, pad)
    res = jnp.asarray(rng.standard_normal((n, m, e, f)).astype(np.float32))
    kw = dict(stride=stride, padding=pad, bias=bias, fuse_relu=True,
              residual=res)
    got = bsr_conv(x, q, interpret=True, **kw)
    mirror = bsr_conv_blocked_ref(x, q, **kw)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(mirror, np.float32))
    te, tf = max(1, (e + 1) // 2), max(1, f // 2 + 1)   # non-dividing tiles
    got_tiled = bsr_conv(x, q, te=te, tf=tf, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(got_tiled), np.asarray(got),
                               rtol=1e-5, atol=1e-5)
    ref = bsr_conv_ref(x, jnp.asarray(wt), stride=stride, padding=pad)
    ref = np.asarray(jax.nn.relu(ref + bias[None, :, None, None] + res))
    rel = np.linalg.norm(np.asarray(got, np.float32) - ref) / np.linalg.norm(ref)
    assert rel < 0.05, rel
