"""Data pipeline determinism, checkpoint commit/restore/GC, fault-tolerance
runtime (straggler monitor, failure retry with restore)."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_state, save_state
from repro.data import DataConfig, SyntheticLMDataset, make_loader
from repro.runtime import FailureDetector, StepRunner, StragglerMonitor, plan_remesh


# --------------------------- data pipeline ---------------------------------

def test_data_deterministic_by_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100, seed=7)
    ds = SyntheticLMDataset(cfg)
    a, b = ds.batch_for(5), ds.batch_for(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_for(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_disjoint():
    h0 = DataConfig(seq_len=8, global_batch=8, vocab=100, seed=1, n_hosts=2,
                    host_id=0)
    h1 = DataConfig(seq_len=8, global_batch=8, vocab=100, seed=1, n_hosts=2,
                    host_id=1)
    b0 = SyntheticLMDataset(h0).batch_for(3)
    b1 = SyntheticLMDataset(h1).batch_for(3)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_loader_resume_mid_stream():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50, seed=3)
    l1 = make_loader(cfg, start_step=0)
    seq1 = [next(l1)["tokens"] for _ in range(4)]
    l1.close()
    l2 = make_loader(cfg, start_step=2)  # restart-from-checkpoint semantics
    seq2 = [next(l2)["tokens"] for _ in range(2)]
    l2.close()
    np.testing.assert_array_equal(seq1[2], seq2[0])
    np.testing.assert_array_equal(seq1[3], seq2[1])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50)
    b = SyntheticLMDataset(cfg).batch_for(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# --------------------------- checkpointing ---------------------------------

def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.float32)},
            "opt": {"step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    st = _state()
    save_state(st, str(tmp_path), 7)
    assert latest_step(str(tmp_path)) == 7
    like = jax.eval_shape(lambda: _state())
    back = restore_state(like, str(tmp_path), 7)
    np.testing.assert_array_equal(np.asarray(back["params"]["w"], np.float32),
                                  np.asarray(st["params"]["w"], np.float32))
    assert back["params"]["w"].dtype == jnp.bfloat16
    assert int(back["opt"]["step"]) == 7


def test_uncommitted_checkpoint_ignored(tmp_path):
    st = _state()
    save_state(st, str(tmp_path), 5)
    d = pathlib.Path(tmp_path) / "step_000009"
    d.mkdir()  # crashed mid-write: no COMMIT
    assert latest_step(str(tmp_path)) == 5


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (10, 20, 30):
        mgr.save_async(st, s)
    mgr.wait()
    mgr._gc()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [20, 30]
    back, step = mgr.restore_latest(jax.eval_shape(lambda: _state()))
    assert step == 30 and back is not None


# --------------------------- fault tolerance --------------------------------

def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup_steps=3)
    for _ in range(20):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)


def test_straggler_monitor_host_lag():
    mon = StragglerMonitor()
    lag = mon.observe_hosts({0: 1.0, 1: 1.1, 2: 9.0, 3: 0.9})
    assert lag == [2]


def test_failure_detector_classification():
    det = FailureDetector(max_strikes=2)
    assert det.classify(RuntimeError("collective timeout DEADLINE_EXCEEDED")) \
        == "retryable"
    assert det.classify(ValueError("shape mismatch")) == "fatal"
    assert det.record(RuntimeError("UNAVAILABLE")) == "retryable"
    assert det.record(RuntimeError("UNAVAILABLE")) == "escalate"


def test_step_runner_restart_after_failure(tmp_path):
    """Induce a transient failure mid-run; the runner must restore the last
    committed checkpoint and converge to the same final state as an
    uninterrupted run (determinism across restarts)."""
    from repro.data import DataConfig, make_loader
    calls = {"n": 0, "failed": False}

    def flaky_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 6 and not calls["failed"]:
            calls["failed"] = True
            raise RuntimeError("transient collective UNAVAILABLE")
        s = state["s"] + int(batch["tokens"].sum()) % 97
        return {"s": s}, {"loss": float(s)}

    dcfg = DataConfig(seq_len=4, global_batch=2, vocab=13, seed=0)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    runner = StepRunner(flaky_step, mgr, lambda s: make_loader(dcfg, s),
                        ckpt_every=2)
    final, end = runner.run({"s": 0}, 0, 8)
    assert end == 8 and calls["failed"]

    # uninterrupted reference
    def clean_step(state, batch):
        return {"s": state["s"] + int(batch["tokens"].sum()) % 97}, {"loss": 0.0}
    mgr2 = CheckpointManager(str(tmp_path / "ref"), keep=3)
    runner2 = StepRunner(clean_step, mgr2, lambda s: make_loader(dcfg, s),
                         ckpt_every=100)
    ref, _ = runner2.run({"s": 0}, 0, 8)
    assert final["s"] == ref["s"]


def test_plan_remesh():
    assert plan_remesh(256, model=16) == ((16, 16), ("data", "model"))
    assert plan_remesh(200, model=16) == ((8, 16), ("data", "model"))
    assert plan_remesh(512, model=16, pod_axis=True) == (
        (2, 16, 16), ("pod", "data", "model"))
    assert plan_remesh(15, model=16) is None
