"""Pre-flight static verifier: rule packs, fixtures, CLI, strict bind.

Everything here is static Python over shapes, plan documents, and parsed
ASTs — no kernel launches, no jit compiles (the engine strict-bind test
binds but never executes)."""
import json
import os
import textwrap

import numpy as np
import pytest

from repro.analysis import Diagnostic, PreflightError, REASON_RULES
from repro.analysis import ast_lints, plan_rules, program_rules
from repro.analysis.checker import (ALL_RULES, DEFAULT_NETS,
                                    default_kernel_paths, default_plan_path,
                                    run_check)
from repro.analysis.cli import main as cli_main
from repro.engine import CnnEngine, init_conv_params, lower
from repro.engine.program import ConvOp, Program, ReluOp
from repro.models import cnn
from repro.tuning.cache import PlanEntry

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "plan_caches")


def rules_of(diags, severity=None):
    return {d.rule for d in diags
            if severity is None or d.severity == severity}


# ---------------------------------------------------------------------------
# diagnostics vocabulary
# ---------------------------------------------------------------------------

def test_every_fallback_reason_has_a_static_rule():
    """The verifier's core contract: each runtime fallback reason code has
    a static rule that would have caught it pre-flight."""
    from repro.telemetry.fallback import REASONS

    assert set(REASON_RULES) == set(REASONS)
    for rule in REASON_RULES.values():
        assert rule in ALL_RULES, rule


def test_diagnostic_severity_validated():
    with pytest.raises(ValueError):
        Diagnostic(rule="x", severity="fatal", message="m")


def test_rule_catalogue_ids_are_dotted_and_unique():
    for rule, (severity, doc) in ALL_RULES.items():
        pack, _, name = rule.partition(".")
        assert pack in ("sched", "plan", "prog", "lint") and name, rule
        assert severity in ("error", "warning", "info")
        assert doc


# ---------------------------------------------------------------------------
# plan-cache rules: known-bad fixtures -> exact rule ids
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture, rule, severity", [
    ("stale_v4_bsr.json", "plan.stale_bsr_no_block", "error"),
    ("nondividing_tm.json", "sched.nondividing_tm", "error"),
    ("vmem_busting_tiling.json", "sched.vmem_tiling", "error"),
    ("vmem_busting_pipeline.json", "sched.pipeline_demoted", "warning"),
    ("bad_key.json", "plan.key_unparsable", "error"),
    ("fp8_on_cpu.json", "sched.value_dtype", "error"),
    ("bad_value_dtype.json", "sched.value_dtype", "error"),
])
def test_known_bad_fixture(fixture, rule, severity):
    diags = plan_rules.check_plan_file(os.path.join(FIXTURES, fixture))
    assert rule in rules_of(diags, severity), [d.format() for d in diags]


def test_pipeline_fixture_demotes_but_does_not_error():
    """The VMEM-busting *pipelined* tiling fits unpipelined: the kernel
    silently runs the blocking schedule, so the finding is a warning, not
    a dispatch error."""
    diags = plan_rules.check_plan_file(
        os.path.join(FIXTURES, "vmem_busting_pipeline.json"))
    assert not rules_of(diags, "error")


def test_plan_rules_unreadable_and_schema(tmp_path):
    p = tmp_path / "corrupt.json"
    p.write_text("{not json")
    assert rules_of(plan_rules.check_plan_file(str(p))) == {"plan.unreadable"}
    p2 = tmp_path / "future.json"
    p2.write_text('{"version": 999, "entries": {}}')
    assert rules_of(plan_rules.check_plan_file(str(p2))) == {
        "plan.schema_version"}
    assert rules_of(plan_rules.check_plan_file(str(tmp_path / "absent.json")),
                    ) == {"plan.unreadable"}


def test_plan_rules_unknown_method_and_structure_tag(tmp_path):
    key = "m64_c32_h14w14_r3s3_st1_p1_n1_ep10_sp0.7_float32_cpu"
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"version": 5, "entries": {
        key: {"method": "winograd"},
        key + "_bk9.5": {"method": "dense"},
    }}))
    rules = rules_of(plan_rules.check_plan_file(str(p)), "error")
    assert "plan.unknown_method" in rules
    assert "plan.structure_tag" in rules


def test_plan_rules_geometry_mismatch(tmp_path):
    # Parses fine but 5x5 kernel cannot fit a 3x3 unpadded input.
    key = "m64_c32_h3w3_r5s5_st1_p0_n1_ep10_sp0.7_float32_cpu"
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"version": 5, "entries": {
        key: {"method": "dense"}}}))
    assert rules_of(plan_rules.check_plan_file(str(p)), "error") == {
        "plan.geometry_mismatch"}


def test_shipped_default_plans_are_clean():
    for net in DEFAULT_NETS:
        path = default_plan_path(net)
        assert path is not None, f"no shipped plan for {net}"
        diags = plan_rules.check_plan_file(path)
        assert not rules_of(diags, "error"), [d.format() for d in diags]


# ---------------------------------------------------------------------------
# program rules
# ---------------------------------------------------------------------------

def _conv(name, src, out, c, h, w, m, k, stride, pad, e, f, **kw):
    return ConvOp(name=name, src=src, out=out, c=c, h=h, w=w, m=m, k=k,
                  stride=stride, pad=pad, sparsity=0.7, e=e, f=f, **kw)


def test_program_rules_clean_on_real_nets():
    for net in DEFAULT_NETS:
        program = lower(cnn.NETWORKS[net](), (3, 224, 224))
        diags = program_rules.check_program(program, net=net)
        assert not diags, [d.format() for d in diags]


def test_program_rules_geometry_chain():
    op = _conv("c1", 0, 1, c=3, h=8, w=8, m=4, k=3, stride=1, pad=1,
               e=9, f=9)  # arithmetic says 8x8
    prog = Program(ops=(op,), out=1, in_shape=(3, 8, 8), conv_table=())
    assert "prog.geometry_chain" in rules_of(
        program_rules.check_program(prog), "error")


def test_program_rules_input_mismatch():
    op = _conv("c1", 0, 1, c=16, h=8, w=8, m=4, k=3, stride=1, pad=1,
               e=8, f=8)  # input is (3, 8, 8), not (16, 8, 8)
    prog = Program(ops=(op,), out=1, in_shape=(3, 8, 8), conv_table=())
    assert "prog.geometry_chain" in rules_of(
        program_rules.check_program(prog), "error")


def test_program_rules_ssa_and_out():
    op1 = _conv("c1", 0, 1, c=3, h=8, w=8, m=4, k=3, stride=1, pad=1,
                e=8, f=8)
    op2 = _conv("c2", 5, 2, c=4, h=8, w=8, m=4, k=3, stride=1, pad=1,
                e=8, f=8)  # src 5 never defined
    prog = Program(ops=(op1, op2), out=9, in_shape=(3, 8, 8), conv_table=())
    rules = rules_of(program_rules.check_program(prog), "error")
    assert "prog.ssa_form" in rules
    assert "prog.out_undefined" in rules


def test_program_rules_epilogue_signature():
    sc = _conv("proj", 0, 1, c=3, h=8, w=8, m=8, k=1, stride=1, pad=0,
               e=8, f=8)
    tail = _conv("tail", 0, 2, c=3, h=8, w=8, m=4, k=3, stride=1, pad=1,
                 e=8, f=8, res=1)  # shortcut is (8, 8, 8), conv out (4, 8, 8)
    prog = Program(ops=(sc, tail), out=2, in_shape=(3, 8, 8), conv_table=())
    assert "prog.epilogue_signature" in rules_of(
        program_rules.check_program(prog), "error")


def test_program_rules_unfused_relu_and_dead_value():
    op1 = _conv("c1", 0, 1, c=3, h=8, w=8, m=4, k=3, stride=1, pad=1,
                e=8, f=8)
    relu = ReluOp(src=1, out=2)
    dead = _conv("c2", 0, 3, c=3, h=8, w=8, m=4, k=3, stride=1, pad=1,
                 e=8, f=8)
    prog = Program(ops=(op1, relu, dead), out=2, in_shape=(3, 8, 8),
                   conv_table=())
    rules = rules_of(program_rules.check_program(prog), "warning")
    assert "prog.unfused_relu" in rules
    assert "prog.dead_value" in rules


# ---------------------------------------------------------------------------
# AST lints
# ---------------------------------------------------------------------------

def _lint(tmp_path, source):
    p = tmp_path / "kern.py"
    p.write_text(textwrap.dedent(source))
    return ast_lints.check_source(str(p))


def test_lint_traced_branch(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref):
            i = pl.program_id(0)
            j = i * 2
            if j > 0:
                o_ref[0] = x_ref[0]
    """)
    assert rules_of(diags) == {"lint.traced_branch"}


def test_lint_traced_branch_on_ref_load(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(nnz_ref, o_ref):
            n = nnz_ref[0]
            while n > 0:
                n = n - 1
    """)
    assert "lint.traced_branch" in rules_of(diags)


def test_lint_static_branch_ok(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref, *, pipeline: bool):
            i = pl.program_id(0)
            if pipeline:
                o_ref[0] = x_ref[i] * 2
            hi = i + 1 if pipeline else 0
    """)
    assert not diags


def test_lint_grid_alloc(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref):
            def body(k, acc):
                t = jnp.zeros((8,), dtype=jnp.float32)
                return acc + t
            acc = lax.fori_loop(0, 4, body, jnp.zeros((8,), jnp.float32))
            o_ref[...] = acc
    """)
    assert rules_of(diags) == {"lint.grid_alloc"}


def test_lint_grid_alloc_outer_loop_ok(tmp_path):
    # Allocation in a loop body that itself runs fori_loop (the per-channel
    # accumulator pattern of the sparse conv kernel) is allowed.
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref):
            def channel(ml, _):
                def body(k, acc):
                    return acc + x_ref[ml, k]
                acc0 = jnp.zeros((8,), dtype=jnp.float32)
                o_ref[ml] = lax.fori_loop(0, 4, body, acc0)
                return 0
            lax.fori_loop(0, 8, channel, 0)
    """)
    assert not diags


def test_lint_accum_dtype(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref):
            acc = jnp.zeros((8, 8))
            o_ref[...] = acc
    """)
    assert rules_of(diags) == {"lint.accum_dtype"}


def test_lint_accum_dtype_positional_and_like_ok(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref):
            a = jnp.zeros((8,), jnp.float32)
            b = jnp.full((8,), -1e30, jnp.float32)
            c = jnp.zeros_like(o_ref)
            o_ref[...] = a + b + c
    """)
    assert not diags


def test_lint_dma_pairing(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref, xblk_ref, sem):
            dma = pltpu.make_async_copy(x_ref, xblk_ref, sem)
            dma.start()
            o_ref[...] = xblk_ref[...]
    """)
    assert rules_of(diags) == {"lint.dma_pairing"}


def test_lint_dma_paired_ok(tmp_path):
    diags = _lint(tmp_path, """
        def _kernel(x_ref, o_ref, xblk_ref, sem):
            dma = pltpu.make_async_copy(x_ref, xblk_ref, sem)
            dma.start()
            dma.wait()
            o_ref[...] = xblk_ref[...]
    """)
    assert not diags


def test_lint_skips_non_kernel_functions(tmp_path):
    diags = _lint(tmp_path, """
        def wrapper(x, w):
            if x.sum() > 0:
                return jnp.zeros((8,))
            return x
    """)
    assert not diags


def test_repo_kernel_sources_pass_lints():
    """The shipped Pallas kernels satisfy their own hygiene rules."""
    paths = default_kernel_paths()
    assert paths
    diags = ast_lints.check_paths(paths)
    assert not diags, [d.format() for d in diags]


# ---------------------------------------------------------------------------
# full sweep + CLI
# ---------------------------------------------------------------------------

def test_run_check_all_nets_and_shipped_plans_zero_errors():
    """The acceptance gate: every net, its shipped default plan, and the
    kernel sources verify clean."""
    report = run_check()
    assert report.ok, [d.format() for d in report.errors]
    assert not report.warnings, [d.format() for d in report.warnings]
    assert any(c.startswith("net:") for c in report.checked)
    assert any(c.startswith("plan:") for c in report.checked)
    assert any(c.startswith("lint:") for c in report.checked)


def test_run_check_flags_bad_cache():
    report = run_check(
        nets=["alexnet"],
        plan_caches=[os.path.join(FIXTURES, "stale_v4_bsr.json")],
    )
    assert not report.ok
    assert "plan.stale_bsr_no_block" in rules_of(report.errors)


def test_cli_json_and_exit_codes(tmp_path, capsys):
    rc = cli_main(["check", "--net", "alexnet", "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0 and doc["ok"] is True
    assert doc["counts"]["error"] == 0
    rc = cli_main([
        "check", "--net", "alexnet", "--no-lints",
        "--plan-cache", os.path.join(FIXTURES, "nondividing_tm.json"),
    ])
    capsys.readouterr()
    assert rc == 1


def test_cli_rules_catalogue(capsys):
    assert cli_main(["rules"]) == 0
    out = capsys.readouterr().out
    for rule in ALL_RULES:
        assert rule in out


# ---------------------------------------------------------------------------
# engine strict mode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def alexnet_bound():
    program = lower(cnn.NETWORKS["alexnet"](), (3, 224, 224))
    params = init_conv_params(program, np.random.default_rng(0))
    return program, params


def test_strict_bind_clean(alexnet_bound):
    program, params = alexnet_bound
    CnnEngine(program, params, strict=True)  # does not raise


def test_strict_bind_rejects_poisoned_plan(alexnet_bound):
    program, params = alexnet_bound
    name = next(op.name for op in program.conv_ops if op.sparsity > 0)
    plan = {name: PlanEntry(method="pallas", tm=7, pad_to=8, te=8, tf=8)}
    with pytest.raises(PreflightError) as exc:
        CnnEngine(program, params, plan, strict=True)
    assert {d.rule for d in exc.value.diagnostics} == {
        "sched.nondividing_tm"}
    # Non-strict bind keeps the historical permissive behaviour.
    CnnEngine(program, params, plan)


def test_strict_bind_rejects_stale_bsr_plan(alexnet_bound):
    program, params = alexnet_bound
    name = next(op.name for op in program.conv_ops if op.sparsity > 0)
    plan = {name: PlanEntry(method="bsr")}
    with pytest.raises(PreflightError) as exc:
        CnnEngine(program, params, plan, strict=True)
    assert {d.rule for d in exc.value.diagnostics} == {
        "plan.stale_bsr_no_block"}
