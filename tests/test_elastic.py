"""Elastic scaling integration: checkpoint on one mesh layout, restore onto
another (the 1000-node failover path), in a forced-8-device subprocess."""
import subprocess
import sys
import textwrap


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs as cfgs
    from repro.checkpoint import restore_state, save_state
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import init_state, make_train_step, state_shardings
    from repro.optim import AdamWConfig
    from repro.runtime import plan_remesh, build_mesh

    cfg = cfgs.get_config("qwen1.5-0.5b", smoke=True)
    opt_cfg = AdamWConfig(lr=1e-3)

    # --- train one step on a (4, 2) mesh, checkpoint ---
    mesh_a = make_mesh((4, 2), ("data", "model"))
    with mesh_a, shd.use_rules(shd.default_rules(mesh_a), mesh_a):
        ns_a = state_shardings(cfg, mesh_a, 2)
        step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=10),
                       in_shardings=(ns_a, None), out_shardings=(ns_a, None))
        state = jax.device_put(init_state(cfg, opt_cfg, jax.random.PRNGKey(0)),
                               ns_a)
        key = jax.random.PRNGKey(1)
        toks = jax.random.randint(key, (8, 32), 0, cfg.vocab, jnp.int32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        state, m1 = step(state, batch)
        save_state(state, "/tmp/elastic_ckpt", 1)

    # --- "lose" 4 devices: re-mesh to (2, 2) and restore ---
    plan = plan_remesh(4, model=2)
    assert plan == ((2, 2), ("data", "model")), plan
    mesh_b = build_mesh(plan, devices=jax.devices()[:4])
    with mesh_b, shd.use_rules(shd.default_rules(mesh_b), mesh_b):
        ns_b = state_shardings(cfg, mesh_b, 2)
        like = jax.eval_shape(
            lambda: init_state(cfg, opt_cfg, jax.random.PRNGKey(0)))
        restored = restore_state(like, "/tmp/elastic_ckpt", 1, shardings=ns_b)
        # same logical state, new physical layout
        w_old = np.asarray(jax.device_get(
            jax.tree.leaves(state["params"])[0]), np.float32)
        w_new = np.asarray(jax.device_get(
            jax.tree.leaves(restored["params"])[0]), np.float32)
        np.testing.assert_array_equal(w_old, w_new)
        # and training continues on the smaller mesh
        step_b = jax.jit(make_train_step(cfg, opt_cfg, total_steps=10),
                         in_shardings=(ns_b, None), out_shardings=(ns_b, None))
        restored, m2 = step_b(restored, batch)
        assert np.isfinite(float(m2["loss"]))
        assert int(restored["opt"]["step"]) == 2
    print("ELASTIC_OK", float(m1["loss"]), float(m2["loss"]))
""")


def test_checkpoint_restores_across_mesh_shapes():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
