"""Optimizer, schedule, and gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip module on clean envs
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_int8, cosine_schedule,
                         decompress_int8)


def test_adamw_optimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, g, opt, cfg, jnp.float32(0.05))
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_bf16_state():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    params2, opt2, _ = adamw_update(params, {"w": jnp.ones((4,), jnp.bfloat16)},
                                    opt, cfg, jnp.float32(1e-2))
    assert opt2["v"]["w"].dtype == jnp.bfloat16
    assert not np.isnan(np.asarray(params2["w"], np.float32)).any()


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)
    unclipped, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(unclipped["a"]), [3.0, 4.0], rtol=1e-5)


def test_cosine_schedule():
    assert float(cosine_schedule(jnp.int32(0), peak=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_schedule(jnp.int32(10), peak=1.0, warmup=10,
                                     total=100)) - 1.0) < 1e-5
    end = float(cosine_schedule(jnp.int32(100), peak=1.0, warmup=10, total=100))
    assert abs(end - 0.1) < 1e-5


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.floats(1e-6, 1e4))
def test_int8_compression_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32) * scale)
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    max_err = float(jnp.max(jnp.abs(back - g)))
    assert max_err <= float(s) * 0.5 + 1e-9  # half-ulp of the quant grid


def test_compressed_psum_tree_single_member():
    """On a 1-member axis, compressed psum ~= identity (within quant error)."""
    from repro.optim.compression import compressed_psum_tree
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.linspace(-1, 1, 16)}
    out = jax.shard_map(lambda t: compressed_psum_tree(t, "pod"), mesh=mesh,
                        in_specs=jax.sharding.PartitionSpec(),
                        out_specs=jax.sharding.PartitionSpec())(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=1e-2)
